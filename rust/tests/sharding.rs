//! Shard-solve acceptance tests: merged K-shard fragments reassemble a
//! solve cache whose compiled bitmaps AND saved RCSS bytes are identical
//! to a single-process compile for K ∈ {1, 2, 4, 8}; fragments survive a
//! serialization round-trip; and fragments from a mismatched chip,
//! config, or pipeline fingerprint — or an incomplete/duplicated shard
//! set — are rejected cleanly.
//!
//! The snapshot path gets the same treatment: shards solved from a
//! sealed "RCRG" registry snapshot (no tensor set, no re-scan) produce
//! fragments byte-identical to the tensor-shipping path, and snapshots
//! with the wrong identity, tier, or corrupted bytes are refused.

use rchg::coordinator::{CompileSession, CompiledTensor, Method, ShardFragment, ShardPlan};
use rchg::experiments::compile_time::synthetic_model_tensors;
use rchg::fault::bank::ChipFaults;
use rchg::fault::FaultRates;
use rchg::grouping::GroupConfig;

fn model(cfg: &GroupConfig, limit: usize) -> Vec<(String, Vec<i64>)> {
    synthetic_model_tensors("resnet20", cfg, limit).unwrap()
}

/// One unsharded compile: (per-tensor outputs, saved RCSS bytes).
fn compile_solo(
    cfg: GroupConfig,
    chip: &ChipFaults,
    method: Method,
    tensors: &[(String, Vec<i64>)],
) -> (Vec<(String, CompiledTensor)>, Vec<u8>) {
    let mut session = CompileSession::builder(cfg).method(method).chip(chip);
    for (name, ws) in tensors {
        session.submit(name, ws.clone());
    }
    let out = session.drain();
    (out, session.to_bytes().unwrap())
}

/// Solve all K shards in independent sessions (as separate processes
/// would), round-tripping every fragment through its byte serialization.
fn solve_shards(
    cfg: GroupConfig,
    chip: &ChipFaults,
    method: Method,
    tensors: &[(String, Vec<i64>)],
    shards: usize,
    threads: usize,
) -> Vec<ShardFragment> {
    let plan = ShardPlan::new(shards);
    (0..shards)
        .map(|k| {
            let mut session =
                CompileSession::builder(cfg).method(method).threads(threads).chip(chip);
            for (name, ws) in tensors {
                session.submit(name, ws.clone());
            }
            let fragment = session.solve_shard(&plan, k).unwrap();
            ShardFragment::from_bytes(&fragment.to_bytes()).unwrap()
        })
        .collect()
}

/// Worker-side sessions for the snapshot path: rebuilt from chip +
/// method alone, handed only the sealed registry snapshot — these
/// sessions never see the tensor set.
fn solve_shards_from_snapshot(
    cfg: GroupConfig,
    chip: &ChipFaults,
    snapshot: &[u8],
    shards: usize,
    threads: usize,
) -> Vec<ShardFragment> {
    let plan = ShardPlan::new(shards);
    (0..shards)
        .map(|k| {
            let mut session =
                CompileSession::builder(cfg).method(Method::Complete).threads(threads).chip(chip);
            let fragment = session.solve_shard_from_snapshot(snapshot, &plan, k).unwrap();
            ShardFragment::from_bytes(&fragment.to_bytes()).unwrap()
        })
        .collect()
}

#[test]
fn merged_shards_match_single_process_for_k_1_2_4_8() {
    // Acceptance: for K ∈ {1, 2, 4, 8}, merging K fragments yields (a)
    // compiled bitmaps byte-identical to the unsharded session, (b) zero
    // fresh solves on the merged cache, and (c) an RCSS save byte-equal
    // to the unsharded session's save.
    let cfg = GroupConfig::R2C2;
    let chip = ChipFaults::new(21, FaultRates::paper_default());
    let tensors = model(&cfg, 8_000);
    let (solo_out, solo_bytes) = compile_solo(cfg, &chip, Method::Complete, &tensors);

    for shards in [1usize, 2, 4, 8] {
        let fragments = solve_shards(cfg, &chip, Method::Complete, &tensors, shards, 2);
        // The shard ranges tile the registry: every pattern is owned by
        // exactly one shard, and the per-fragment registry slices agree.
        let n_patterns = fragments[0].total_patterns();
        let covered: usize = fragments.iter().map(|f| f.range().len()).sum();
        assert_eq!(covered, n_patterns, "K={shards} ranges must tile the registry");
        let solved: usize = fragments.iter().map(|f| f.solved_patterns()).sum();
        assert_eq!(solved, n_patterns, "a cold compile solves every pattern once");

        let mut merged = CompileSession::builder(cfg).method(Method::Complete).chip(&chip);
        let installed = merged.merge_fragments(&fragments).unwrap();
        assert_eq!(installed, n_patterns);

        // (c) the merged warm state is byte-identical to the unsharded
        // session's save — before compiling anything through it.
        assert_eq!(
            merged.to_bytes().unwrap(),
            solo_bytes,
            "K={shards} merged RCSS bytes diverged from the single-process save"
        );

        // (a)+(b): compiling the model through the merged cache solves
        // nothing fresh and reproduces the unsharded output bitmaps.
        for (name, ws) in &tensors {
            merged.submit(name, ws.clone());
        }
        let out = merged.drain();
        assert_eq!(out.len(), solo_out.len());
        for ((name, got), (solo_name, want)) in out.iter().zip(&solo_out) {
            assert_eq!(name, solo_name);
            assert_eq!(got.stats.unique_pairs, 0, "K={shards} merged cache must be warm");
            assert_eq!(got.decomps, want.decomps, "K={shards} bitmaps diverged on {name}");
            assert_eq!(got.errors, want.errors, "K={shards} errors diverged on {name}");
        }
        // And the save after recompiling is unchanged too.
        assert_eq!(merged.to_bytes().unwrap(), solo_bytes);
    }
}

#[test]
fn snapshot_shards_are_byte_identical_to_tensor_shards() {
    // Acceptance: for K ∈ {1, 2, 4}, solving every shard from the
    // coordinator's registry snapshot — no tensors, no re-scan — yields
    // fragments byte-identical to the tensor-shipping path, and their
    // merge reproduces the unsharded session's RCSS bytes and bitmaps.
    let cfg = GroupConfig::R2C2;
    let chip = ChipFaults::new(21, FaultRates::paper_default());
    let tensors = model(&cfg, 6_000);
    let (solo_out, solo_bytes) = compile_solo(cfg, &chip, Method::Complete, &tensors);

    let mut coordinator = CompileSession::builder(cfg).method(Method::Complete).chip(&chip);
    for (name, ws) in &tensors {
        coordinator.submit(name, ws.clone());
    }
    let snapshot = coordinator.scan_to_snapshot().unwrap();

    for shards in [1usize, 2, 4] {
        let from_tensors = solve_shards(cfg, &chip, Method::Complete, &tensors, shards, 2);
        let from_snapshot = solve_shards_from_snapshot(cfg, &chip, &snapshot, shards, 2);
        assert_eq!(from_tensors.len(), from_snapshot.len());
        for (a, b) in from_tensors.iter().zip(&from_snapshot) {
            assert_eq!(a.to_bytes(), b.to_bytes(), "K={shards}: fragment bytes diverged");
        }
        let mut merged = CompileSession::from_fragments(&from_snapshot).unwrap();
        assert_eq!(
            merged.to_bytes().unwrap(),
            solo_bytes,
            "K={shards}: merged snapshot-path RCSS diverged from the single-process save"
        );
        for (name, ws) in &tensors {
            merged.submit(name, ws.clone());
        }
        for ((_, got), (_, want)) in merged.drain().iter().zip(&solo_out) {
            assert_eq!(got.stats.unique_pairs, 0, "K={shards}: merged cache must be warm");
            assert_eq!(got.decomps, want.decomps);
            assert_eq!(got.errors, want.errors);
        }
    }
}

#[test]
fn snapshot_solve_guards_identity_tier_and_integrity() {
    let cfg = GroupConfig::R2C2;
    let chip = ChipFaults::new(21, FaultRates::paper_default());
    let tensors = model(&cfg, 2_000);
    let mut coordinator = CompileSession::builder(cfg).method(Method::Complete).chip(&chip);
    for (name, ws) in &tensors {
        coordinator.submit(name, ws.clone());
    }
    let snapshot = coordinator.scan_to_snapshot().unwrap();
    let plan = ShardPlan::new(2);
    let fresh = || CompileSession::builder(cfg).method(Method::Complete).chip(&chip);

    // The happy path works — the rejections below are not spurious.
    assert!(fresh().solve_shard_from_snapshot(&snapshot, &plan, 0).is_ok());

    // A session for a different chip refuses the snapshot.
    let other = ChipFaults::new(22, FaultRates::paper_default());
    let mut wrong_chip = CompileSession::builder(cfg).method(Method::Complete).chip(&other);
    let err = wrong_chip.solve_shard_from_snapshot(&snapshot, &plan, 0).unwrap_err().to_string();
    assert!(err.contains("chip seed"), "unhelpful error: {err}");

    // A different grouping config refuses too (the key carries it).
    let mut wrong_cfg =
        CompileSession::builder(GroupConfig::R1C4).method(Method::Complete).chip(&chip);
    assert!(wrong_cfg.solve_shard_from_snapshot(&snapshot, &plan, 0).is_err());

    // Per-weight tiers have no tensor-free solve: the gate names the tier.
    let mut per_weight = CompileSession::builder(cfg).method(Method::IlpOnly).chip(&chip);
    let err = per_weight.solve_shard_from_snapshot(&snapshot, &plan, 0).unwrap_err().to_string();
    assert!(err.contains("table tier"), "unhelpful error: {err}");

    // Shard index out of range.
    assert!(fresh().solve_shard_from_snapshot(&snapshot, &plan, 2).is_err());

    // Corruption and truncation are rejected by the sealed codec.
    let mut flipped = snapshot.clone();
    let mid = flipped.len() / 2;
    flipped[mid] ^= 0x10;
    assert!(fresh().solve_shard_from_snapshot(&flipped, &plan, 0).is_err());
    assert!(fresh()
        .solve_shard_from_snapshot(&snapshot[..snapshot.len() - 5], &plan, 0)
        .is_err());
    // An RCSS session save is not a registry snapshot.
    let rcss = coordinator.to_bytes().unwrap();
    assert!(fresh().solve_shard_from_snapshot(&rcss, &plan, 0).is_err());
}

#[test]
fn from_fragments_builds_the_session_from_the_key_alone() {
    // The fragment key carries the whole session identity: a coordinator
    // can rebuild the warm session with no other configuration.
    let cfg = GroupConfig::R2C2;
    let chip = ChipFaults::new(33, FaultRates::paper_default());
    let tensors = model(&cfg, 5_000);
    let (solo_out, solo_bytes) = compile_solo(cfg, &chip, Method::Complete, &tensors);

    let fragments = solve_shards(cfg, &chip, Method::Complete, &tensors, 3, 1);
    let mut merged = CompileSession::from_fragments(&fragments).unwrap();
    assert!(merged.matches(&chip, merged.options()));
    assert_eq!(merged.to_bytes().unwrap(), solo_bytes);
    for (name, ws) in &tensors {
        merged.submit(name, ws.clone());
    }
    for ((_, got), (_, want)) in merged.drain().iter().zip(&solo_out) {
        assert_eq!(got.stats.unique_pairs, 0);
        assert_eq!(got.decomps, want.decomps);
    }
}

#[test]
fn per_weight_tier_shards_identically() {
    // The PerWeight tier (paper-protocol baselines) shards by pattern-id
    // range too: pairs of in-range patterns are solved, everything merges
    // back byte-identically. Small tensor set — ILP solves are expensive.
    let cfg = GroupConfig::R2C2;
    let chip = ChipFaults::new(5, FaultRates::paper_default());
    let tensors = vec![("t0".to_string(), (-30..=30).chain(-30..=30).collect::<Vec<i64>>())];
    let (solo_out, solo_bytes) = compile_solo(cfg, &chip, Method::IlpOnly, &tensors);

    let fragments = solve_shards(cfg, &chip, Method::IlpOnly, &tensors, 2, 1);
    let mut merged = CompileSession::builder(cfg).method(Method::IlpOnly).chip(&chip);
    merged.merge_fragments(&fragments).unwrap();
    assert_eq!(merged.to_bytes().unwrap(), solo_bytes);
    for (name, ws) in &tensors {
        merged.submit(name, ws.clone());
    }
    for ((_, got), (_, want)) in merged.drain().iter().zip(&solo_out) {
        assert_eq!(got.stats.unique_pairs, 0);
        assert_eq!(got.decomps, want.decomps);
        assert_eq!(got.errors, want.errors);
    }
}

#[test]
fn thread_count_never_changes_fragments() {
    let cfg = GroupConfig::R2C2;
    let chip = ChipFaults::new(9, FaultRates::paper_default());
    let tensors = model(&cfg, 4_000);
    let a = solve_shards(cfg, &chip, Method::Complete, &tensors, 4, 1);
    let b = solve_shards(cfg, &chip, Method::Complete, &tensors, 4, 8);
    for (fa, fb) in a.iter().zip(&b) {
        assert_eq!(fa.to_bytes(), fb.to_bytes(), "fragments must be thread-count invariant");
    }
}

#[test]
fn mismatched_fingerprints_and_broken_sets_are_rejected() {
    let cfg = GroupConfig::R2C2;
    let chip = ChipFaults::new(21, FaultRates::paper_default());
    let tensors = model(&cfg, 3_000);
    let fragments = solve_shards(cfg, &chip, Method::Complete, &tensors, 2, 1);

    // Wrong chip: same config/pipeline, different seed.
    let other_chip = ChipFaults::new(22, FaultRates::paper_default());
    let mut wrong_chip = CompileSession::builder(cfg).chip(&other_chip);
    let err = wrong_chip.merge_fragments(&fragments).unwrap_err().to_string();
    assert!(err.contains("chip seed"), "unhelpful error: {err}");

    // Wrong grouping config.
    let mut wrong_cfg = CompileSession::builder(GroupConfig::R1C4).chip(&chip);
    assert!(wrong_cfg.merge_fragments(&fragments).is_err());

    // Wrong pipeline fingerprint (different method).
    let mut wrong_method = CompileSession::builder(cfg).method(Method::IlpOnly).chip(&chip);
    let err = wrong_method.merge_fragments(&fragments).unwrap_err().to_string();
    assert!(err.contains("pipeline"), "unhelpful error: {err}");

    // Incomplete set: one of two shards.
    let mut incomplete = CompileSession::builder(cfg).chip(&chip);
    let err = incomplete.merge_fragments(&fragments[..1]).unwrap_err().to_string();
    assert!(err.contains("missing"), "unhelpful error: {err}");

    // Duplicated shard.
    let dup = vec![fragments[0].clone(), fragments[0].clone()];
    let mut duplicated = CompileSession::builder(cfg).chip(&chip);
    assert!(duplicated.merge_fragments(&dup).is_err());

    // Fragments from different plans never mix.
    let three_way = solve_shards(cfg, &chip, Method::Complete, &tensors, 3, 1);
    let mixed = vec![fragments[0].clone(), three_way[1].clone()];
    let mut mixed_session = CompileSession::builder(cfg).chip(&chip);
    let err = mixed_session.merge_fragments(&mixed).unwrap_err().to_string();
    assert!(err.contains("plan"), "unhelpful error: {err}");

    // A detached session has no chip identity to merge into.
    let mut detached = CompileSession::builder(cfg).detached();
    assert!(detached.merge_fragments(&fragments).is_err());

    // And the merge succeeds once everything lines up — the rejections
    // above were not spurious.
    let mut ok = CompileSession::builder(cfg).chip(&chip);
    assert!(ok.merge_fragments(&fragments).is_ok());
}

#[test]
fn corrupted_fragment_bytes_are_rejected() {
    let cfg = GroupConfig::R2C2;
    let chip = ChipFaults::new(2, FaultRates::paper_default());
    let tensors = model(&cfg, 2_000);
    let good = solve_shards(cfg, &chip, Method::Complete, &tensors, 2, 1)[0].to_bytes();
    assert!(ShardFragment::from_bytes(&good).is_ok());

    assert!(ShardFragment::from_bytes(&[]).is_err());
    assert!(ShardFragment::from_bytes(&good[..8]).is_err());
    assert!(ShardFragment::from_bytes(&good[..good.len() - 3]).is_err());
    assert!(ShardFragment::from_bytes(&good[..good.len() / 2]).is_err());

    // A flipped bit mid-payload fails the checksum.
    let mut flipped = good.clone();
    let mid = flipped.len() / 2;
    flipped[mid] ^= 0x40;
    assert!(ShardFragment::from_bytes(&flipped).is_err());

    // Wrong magic / future version (checksum recomputed so only the
    // header field is at fault): an RCSS session file is not a fragment.
    let refresh = |mut bytes: Vec<u8>| -> Vec<u8> {
        let n = bytes.len();
        let sum = rchg::util::prop::fnv1a(&bytes[..n - 8]);
        bytes[n - 8..].copy_from_slice(&sum.to_le_bytes());
        bytes
    };
    let mut magic = good.clone();
    magic[0] ^= 0xFF;
    assert!(ShardFragment::from_bytes(&refresh(magic)).is_err());
    let mut vers = good.clone();
    vers[4] = 99;
    assert!(ShardFragment::from_bytes(&refresh(vers)).is_err());

    // A session cache is not a fragment and vice versa.
    let mut session = CompileSession::builder(cfg).chip(&chip);
    let _ = session.compile_tensor("t", &[0, 1, 2]);
    let rcss = session.to_bytes().unwrap();
    assert!(ShardFragment::from_bytes(&rcss).is_err());
    assert!(CompileSession::from_bytes(&good).is_err());
}

#[test]
fn solve_shard_guards_its_preconditions() {
    let cfg = GroupConfig::R2C2;
    let chip = ChipFaults::new(1, FaultRates::paper_default());
    let plan = ShardPlan::new(2);

    // Shard index out of range.
    let mut s = CompileSession::builder(cfg).chip(&chip);
    s.submit("t", vec![1, 2, 3]);
    assert!(s.solve_shard(&plan, 2).is_err());

    // Nothing submitted.
    let mut empty = CompileSession::builder(cfg).chip(&chip);
    assert!(empty.solve_shard(&plan, 0).is_err());

    // Detached and legacy sessions cannot shard-solve.
    let mut detached = CompileSession::builder(cfg).detached();
    detached.submit("t", vec![1]);
    assert!(detached.solve_shard(&plan, 0).is_err());
    let mut legacy = CompileSession::builder(cfg).dedupe(false).chip(&chip);
    legacy.submit("t", vec![1]);
    assert!(legacy.solve_shard(&plan, 0).is_err());
}
