//! PJRT runtime benchmarks: executable load/compile cost and steady-state
//! inference latency/throughput for every AOT artifact class. The L3 hot
//! path budget (per-batch coordinator overhead vs XLA execute time) comes
//! from here.

use rchg::grouping::{Decomposition, GroupConfig};
use rchg::nn::packing::Planes;
use rchg::runtime::{artifacts_dir, ArgValue, Runtime};
use rchg::util::prng::Rng;
use rchg::util::timer::{bench, bench_header, Timer};

fn main() -> anyhow::Result<()> {
    let art = artifacts_dir();
    if !art.join("manifest.json").exists() {
        eprintln!("artifacts not built — run `make artifacts` first");
        return Ok(());
    }
    let rt = Runtime::new(&art)?;
    println!("platform: {}", rt.platform());
    println!("{}", bench_header());

    // Compile cost per artifact.
    for name in ["imc_linear_r2c2", "cnn_cnn_s_r2c2", "lm_r2c2"] {
        let t = Timer::start();
        let _exe = rt.load(name)?;
        println!("{:<44} {:>10.2?}", format!("compile/{name}"), t.elapsed());
    }

    // Steady-state execution latency: crossbar kernel.
    let cfg = GroupConfig::R2C2;
    let exe = rt.load("imc_linear_r2c2")?;
    let (k, n) = (64usize, 10usize);
    let mut rng = Rng::new(1);
    let ws: Vec<i64> = (0..k * n).map(|_| rng.range_i64(-30, 30)).collect();
    let decomps: Vec<Decomposition> =
        ws.iter().map(|&w| Decomposition::encode_ideal(w, &cfg)).collect();
    let planes = Planes::pack(&decomps, None, k, n, &cfg);
    let x: Vec<f32> = (0..8 * k).map(|_| rng.normal_f32()).collect();
    let sigs: Vec<f32> = cfg.significances().iter().map(|&s| s as f32).collect();
    let stats = bench("execute/imc_linear_r2c2 (8x64x10)", 30, 0.5, || {
        exe.run(&[
            ArgValue::F32(&x),
            ArgValue::F32(&planes.pos),
            ArgValue::F32(&planes.neg),
            ArgValue::F32(&sigs),
        ])
        .unwrap();
    });
    println!("{}", stats.report());

    // CNN batch inference latency (batch 100).
    let exe = rt.load("cnn_cnn_s_r2c2")?;
    let mut args_data: Vec<Vec<f32>> = Vec::new();
    for spec in &exe.args {
        args_data.push((0..spec.len()).map(|_| rng.normal_f32() * 0.1).collect());
    }
    let stats = bench("execute/cnn_cnn_s_r2c2 (batch 100)", 10, 1.0, || {
        let values: Vec<ArgValue> = args_data.iter().map(|d| ArgValue::F32(d)).collect();
        exe.run(&values).unwrap();
    });
    println!("{}", stats.report());
    let per_img = stats.mean_s / 100.0;
    println!("  → {:.2} ms/image, {:.0} images/s", per_img * 1e3, 1.0 / per_img);

    // LM batch inference latency.
    let exe = rt.load("lm_r2c2")?;
    let mut values_store: Vec<(bool, Vec<f32>, Vec<i32>)> = Vec::new();
    for spec in &exe.args {
        if matches!(spec.dtype, rchg::runtime::DType::I32) {
            values_store.push((true, vec![], (0..spec.len()).map(|i| (i % 200) as i32).collect()));
        } else {
            values_store.push((false, (0..spec.len()).map(|_| rng.normal_f32() * 0.05).collect(), vec![]));
        }
    }
    let stats = bench("execute/lm_r2c2 (batch 2 x 96)", 10, 1.0, || {
        let values: Vec<ArgValue> = values_store
            .iter()
            .map(|(is_i, f, i)| if *is_i { ArgValue::I32(i) } else { ArgValue::F32(f) })
            .collect();
        exe.run(&values).unwrap();
    });
    println!("{}", stats.report());
    let toks = 2.0 * 96.0;
    println!(
        "  → {:.1} tokens/s scoring throughput",
        toks / stats.mean_s
    );
    Ok(())
}
