//! Ablation benches for the design choices DESIGN.md calls out:
//! memoization, theorem staging (vs ILP-only), thread scaling,
//! table-vs-ILP crossover, and the remapping baseline comparison.

use rchg::baseline::remap::remap_compile;
use rchg::coordinator::{CompileOptions, CompileSession, CompiledTensor, Method};
use rchg::experiments::compile_time::synthetic_model_weights;
use rchg::fault::bank::ChipFaults;
use rchg::fault::{FaultRates, GroupFaults};
use rchg::grouping::GroupConfig;
use rchg::util::timer::{fmt_dur, Timer};

/// One-shot compile via a throwaway detached session (the removed free
/// function's surface; keeps the ablation timings one-shot by design).
fn compile_tensor(ws: &[i64], faults: &[GroupFaults], opts: &CompileOptions) -> CompiledTensor {
    CompileSession::builder(opts.cfg)
        .options(opts.clone())
        .detached()
        .compile_with_faults(ws, faults)
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let n = if quick { 30_000 } else { 200_000 };
    let cfg = GroupConfig::R1C4;
    let ws = synthetic_model_weights("resnet20", &cfg, n)?;
    let chip = ChipFaults::new(1, FaultRates::paper_default());
    let faults = chip.sample_tensor(0, ws.len(), cfg.cells());

    println!("== ablation: dedupe / memoization ({} weights, R1C4)", ws.len());
    {
        let opts = CompileOptions::new(cfg, Method::Complete);
        let t = Timer::start();
        let out = compile_tensor(&ws, &faults, &opts);
        println!(
            "  pattern-class dedupe {:>10}  ({} classes, {} unique pairs, {:.1}x)",
            fmt_dur(t.secs()),
            out.stats.unique_patterns,
            out.stats.unique_pairs,
            out.stats.dedup_ratio()
        );
    }
    for memo in [true, false] {
        let mut opts = CompileOptions::new(cfg, Method::Complete);
        opts.dedupe = false;
        opts.memoize = memo;
        let t = Timer::start();
        let out = compile_tensor(&ws, &faults, &opts);
        println!(
            "  legacy memoize={memo:<5} {:>10}  (hits {})",
            fmt_dur(t.secs()),
            out.stats.memo_hits
        );
    }

    println!("== ablation: theorem staging (complete vs ILP-only, 2k sample)");
    let small = &ws[..2_000.min(ws.len())];
    let fsmall = &faults[..small.len()];
    for method in [Method::Complete, Method::IlpOnly] {
        let t = Timer::start();
        let out = compile_tensor(small, fsmall, &CompileOptions::new(cfg, method));
        println!(
            "  {method:?}: {} (total|err|={})",
            fmt_dur(t.secs()),
            out.stats.total_abs_error
        );
    }

    println!("== ablation: thread scaling (R2C2, {} weights)", ws.len());
    let cfg2 = GroupConfig::R2C2;
    let ws2 = synthetic_model_weights("resnet20", &cfg2, n)?;
    let faults2 = chip.sample_tensor(0, ws2.len(), cfg2.cells());
    for threads in [1usize, 2, 4] {
        let mut opts = CompileOptions::new(cfg2, Method::Complete);
        opts.threads = threads;
        let t = Timer::start();
        let _ = compile_tensor(&ws2, &faults2, &opts);
        println!("  threads={threads}: {}", fmt_dur(t.secs()));
    }

    println!("== ablation: sparsest-solution mode (R2C2, 20k)");
    let s20 = &ws2[..20_000.min(ws2.len())];
    let f20 = &faults2[..s20.len()];
    for sparsest in [false, true] {
        let mut opts = CompileOptions::new(cfg2, Method::Complete);
        opts.pipeline.sparsest = sparsest;
        let t = Timer::start();
        let out = compile_tensor(s20, f20, &opts);
        let l1: u64 = out.decomps.iter().map(|d| d.l1()).sum();
        println!(
            "  sparsest={sparsest:<5} {:>10}  (Σ‖X‖₁ = {l1})",
            fmt_dur(t.secs())
        );
    }

    println!("== baseline comparison: residual error per method (R1C4, 20k)");
    let s = &ws[..20_000.min(ws.len())];
    let f = &faults[..s.len()];
    let raw = compile_tensor(s, f, &CompileOptions::new(cfg, Method::Unprotected));
    let remap = remap_compile(s, f, &cfg);
    let pipe = compile_tensor(s, f, &CompileOptions::new(cfg, Method::Complete));
    println!("  unprotected  total|err| = {}", raw.stats.total_abs_error);
    println!("  row-remap    total|err| = {}", remap.total_abs_error);
    println!("  pipeline     total|err| = {}", pipe.stats.total_abs_error);

    println!("== 1-bit cells (L=2): paper's other cell resolution");
    for name in ["r1c8@2", "r2c4@2"] {
        let c = GroupConfig::parse(name).unwrap();
        let w1 = synthetic_model_weights("resnet20", &c, 20_000)?;
        let f1 = chip.sample_tensor(0, w1.len(), c.cells());
        let t = Timer::start();
        let out = compile_tensor(&w1, &f1, &CompileOptions::new(c, Method::Complete));
        println!(
            "  {name:<8} ({:.2} bit): {} — imperfect {:.3}%",
            c.precision_bits(),
            fmt_dur(t.secs()),
            100.0 * out.stats.imperfect as f64 / w1.len() as f64
        );
    }
    Ok(())
}
