//! ILP solver microbenchmarks: simplex + branch-and-bound cost on the
//! decomposition problem family, vs problem size.

use rchg::ilp::{Cmp, IlpProblem};
use rchg::util::prng::Rng;
use rchg::util::timer::{bench, bench_header, black_box};

fn random_decomposition_ilp(rng: &mut Rng, nvars: usize, levels: i64) -> IlpProblem {
    // min Σx s.t. Σ ±sig·x = w, 0 ≤ x ≤ L−1 — the FAWD family.
    let mut p = IlpProblem::new(nvars);
    p.minimize(&vec![1; nvars]);
    let mut coeffs = Vec::with_capacity(nvars);
    let mut max_abs = 0i64;
    for j in 0..nvars {
        let sig = levels.pow((j % 4) as u32);
        let s = if j % 2 == 0 { sig } else { -sig };
        coeffs.push(s);
        max_abs += sig * (levels - 1);
        p.bound(j, 0, levels - 1);
    }
    let w = rng.range_i64(-max_abs / 2, max_abs / 2);
    p.add(&coeffs, Cmp::Eq, w);
    p
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let iters = if quick { 10 } else { 50 };
    println!("{}", bench_header());
    let mut rng = Rng::new(3);

    // 2*r*c(+t) tops out at 17 for every paper configuration (R2C4 → 16+1);
    // beyond that the exact-rational B&B needs stronger pruning than this
    // reproduction justifies (Gurobi territory — see EXPERIMENTS.md).
    for nvars in [4usize, 8, 12, 16] {
        let problems: Vec<IlpProblem> =
            (0..64).map(|_| random_decomposition_ilp(&mut rng, nvars, 4)).collect();
        let mut i = 0usize;
        // Large instances take seconds per solve — cap their iteration
        // counts so the harness stays bounded.
        let iters = if nvars >= 16 { 8.min(iters) } else { iters };
        let stats = bench(&format!("fawd-ilp/{nvars}-vars"), iters, 0.1, || {
            i = (i + 1) % problems.len();
            black_box(problems[i].solve());
        });
        println!("{}", stats.report());
    }

    // LP relaxation only (simplex cost isolated): boxes without integrality
    // pressure (loose rhs).
    for nvars in [8usize, 16, 32] {
        let mut p = IlpProblem::new(nvars);
        p.minimize(&vec![1; nvars]);
        for j in 0..nvars {
            p.bound(j, 0, 3);
        }
        p.add(&vec![1; nvars], Cmp::Ge, nvars as i64); // achievable integrally
        let stats = bench(&format!("lp-heavy/{nvars}-vars"), iters, 0.2, || {
            black_box(p.solve());
        });
        println!("{}", stats.report());
    }
}
