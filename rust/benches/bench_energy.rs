//! Fig 11 bench: energy-model sweep across networks × array sizes ×
//! grouping configs × mapper policies, plus model evaluation cost itself.

use rchg::arrays::models::{resnet18, resnet20, total_params};
use rchg::arrays::{ArrayDims, MapperPolicy};
use rchg::energy::{network_energy, EnergyParams};
use rchg::experiments::hw::fig11;
use rchg::grouping::GroupConfig;
use rchg::util::timer::{bench, bench_header, black_box};

fn main() -> anyhow::Result<()> {
    let p = EnergyParams::default();
    for model in ["resnet20", "resnet18"] {
        for policy in [MapperPolicy::KernelSplit, MapperPolicy::PackedVertical] {
            let t = fig11(model, &[64, 128, 256, 512], &p, policy)?;
            println!("{}", t.render());
        }
    }

    println!(
        "(model sizes: resnet20 {} / resnet18 {} weights)",
        total_params(&resnet20()),
        total_params(&resnet18())
    );

    println!("{}", bench_header());
    let layers = resnet18();
    let stats = bench("energy-model/resnet18-full-sweep", 20, 0.2, || {
        for n in [64usize, 128, 256, 512] {
            for cfg in [GroupConfig::R1C4, GroupConfig::R2C2] {
                black_box(network_energy(
                    &layers,
                    ArrayDims::square(n),
                    &cfg,
                    &p,
                    MapperPolicy::KernelSplit,
                ));
            }
        }
    });
    println!("{}", stats.report());
    Ok(())
}
