//! Table II / Fig 10 bench: compilation throughput of every method ×
//! config on real layer shapes. `cargo bench --bench bench_compile`.
//!
//! Full-model times for slow methods are extrapolated from deterministic
//! samples (printed explicitly). The complete pipeline additionally runs a
//! full-scale ResNet-20 compile (no sampling) as a ground-truth datapoint,
//! reports the pattern-class dedup factor (solver invocations vs weights),
//! and cross-checks that the dedupe-first core is byte-identical to the
//! legacy per-weight path at several thread counts.

use rchg::coordinator::{CompileOptions, CompileSession, CompiledTensor, Method, SolveTier};
use rchg::experiments::bench::{compile_sample, BENCH_CHIP_SEED, BENCH_MODEL};
use rchg::experiments::compile_time::{
    dedup_report, fig10a, fig10b, measure, synthetic_model_tensors, synthetic_model_weights,
    table2, CompileTimeOptions,
};
use rchg::fault::bank::ChipFaults;
use rchg::fault::{FaultRates, GroupFaults};
use rchg::grouping::GroupConfig;
use rchg::obs;
use rchg::util::timer::{black_box, fmt_dur, Timer};

/// One-shot compile via a throwaway detached session (the removed free
/// function's surface).
fn compile_tensor(ws: &[i64], faults: &[GroupFaults], opts: &CompileOptions) -> CompiledTensor {
    CompileSession::builder(opts.cfg)
        .options(opts.clone())
        .detached()
        .compile_with_faults(ws, faults)
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let opts = CompileTimeOptions {
        models: if quick {
            vec![BENCH_MODEL.into()]
        } else {
            vec![BENCH_MODEL.into(), "resnet18".into(), "resnet50".into(), "vgg16".into()]
        },
        // Shared with `rchg bench` (experiments::bench) so this bench and
        // the harness sample identical workloads.
        sample_complete: compile_sample(quick),
        sample_ilp: if quick { 500 } else { 2_000 },
        sample_ff: if quick { 500 } else { 2_000 },
        threads: 1,
        include_r2c4: false,
    };

    let (t, rows) = table2(&opts)?;
    println!("{}", t.render());
    println!("{}", fig10a(&rows, &opts.models).render());
    println!("{}", fig10b(&rows, opts.models.last().unwrap()).render());
    println!("{}", dedup_report(&rows).render());

    // Ground-truth full-scale run: complete pipeline on all of ResNet-20,
    // with the dedup factor (weights per solver invocation) per config.
    println!("== full-scale (no sampling) complete-pipeline runs");
    let mut best_ratio = 1.0f64;
    for cfg in [GroupConfig::R1C4, GroupConfig::R2C2] {
        let r = measure(BENCH_MODEL, cfg, Method::Complete, usize::MAX, 1, BENCH_CHIP_SEED)?;
        println!(
            "  resnet20 {} complete: {} for {} weights ({:.0} weights/s) — \
             {} classes, {} unique pairs, {:.1}x dedup",
            cfg.name(),
            fmt_dur(r.measured_secs),
            r.sampled_weights,
            r.sampled_weights as f64 / r.measured_secs,
            r.unique_patterns,
            r.unique_pairs,
            r.dedup_ratio()
        );
        best_ratio = best_ratio.max(r.dedup_ratio());
    }
    println!(
        "  dedup criterion (solver on ≥5x fewer pairs than weights): {}",
        if best_ratio >= 5.0 { "PASS" } else { "FAIL" }
    );

    // Byte-equivalence: the pattern-class path must match the legacy
    // per-weight path exactly, at any thread count.
    println!("== pattern-class vs legacy per-weight equivalence (resnet20 sample)");
    let cfg = GroupConfig::R2C2;
    let n = if quick { 40_000 } else { 120_000 };
    let ws = synthetic_model_weights(BENCH_MODEL, &cfg, n)?;
    let chip = ChipFaults::new(BENCH_CHIP_SEED, FaultRates::paper_default());
    let faults = chip.sample_tensor(0, ws.len(), cfg.cells());
    let mut legacy = CompileOptions::new(cfg, Method::Complete);
    legacy.dedupe = false;
    let base = compile_tensor(&ws, &faults, &legacy);
    for threads in [1usize, 4, 8] {
        let mut o = CompileOptions::new(cfg, Method::Complete);
        o.threads = threads;
        let out = compile_tensor(&ws, &faults, &o);
        assert_eq!(out.decomps, base.decomps, "decompositions diverged at threads={threads}");
        assert_eq!(out.errors, base.errors, "errors diverged at threads={threads}");
        println!(
            "  threads={threads}: byte-identical to legacy ({} weights, {} unique pairs, {})",
            ws.len(),
            out.stats.unique_pairs,
            fmt_dur(out.stats.wall_secs)
        );
    }

    // Pattern-table criterion: on the BatchTable tier the fresh solve
    // unit is a pattern (one full-range table build), not a (pattern,
    // weight) pair — the per-pattern sweep count must drop ≥2x vs the
    // pair-cache baseline on R2C2, with bounded resident table memory.
    println!("== pattern-table tier vs pair-cache baseline (resnet20 {n} weights, R2C2)");
    let mut table_opts = CompileOptions::new(cfg, Method::Complete);
    table_opts.threads = 1;
    let mut pair_opts = table_opts.clone();
    pair_opts.tier = SolveTier::PerWeight;
    let t_table = Timer::start();
    let table_out = compile_tensor(&ws, &faults, &table_opts);
    let table_secs = t_table.secs();
    let t_pair = Timer::start();
    let pair_out = compile_tensor(&ws, &faults, &pair_opts);
    let pair_secs = t_pair.secs();
    assert_eq!(table_out.decomps, pair_out.decomps, "tiers must be byte-identical");
    assert_eq!(table_out.errors, pair_out.errors);
    let table_sweeps = table_out.stats.pattern_tables_built;
    let pair_sweeps = pair_out.stats.unique_pairs;
    println!(
        "  BatchTable: {} table builds in {} — PerWeight: {} pair sweeps in {}",
        table_sweeps,
        fmt_dur(table_secs),
        pair_sweeps,
        fmt_dur(pair_secs),
    );
    println!(
        "  resident table memory: {} bytes (budget {}), evictions {}",
        table_out.stats.resident_table_bytes,
        table_opts.table_memory_bytes,
        table_out.stats.table_evictions,
    );
    println!(
        "  pattern-table criterion (≥2x fewer fresh solve sweeps): {}",
        if table_sweeps * 2 <= pair_sweeps { "PASS" } else { "FAIL" }
    );
    assert!(
        table_sweeps * 2 <= pair_sweeps,
        "pattern tables must sweep ≥2x less than the pair cache ({table_sweeps} vs {pair_sweeps})"
    );
    assert!(
        table_out.stats.resident_table_bytes <= table_opts.table_memory_bytes,
        "resident table memory exceeds the budget"
    );

    // Session warm-start: save → load → recompile the same model must skip
    // ≥90% of solves (it skips all of them — the chip's fault pattern is
    // fixed) and stay byte-identical to the cold compile.
    println!("== session warm-start (save → load → recompile)");
    let tensors = synthetic_model_tensors(BENCH_MODEL, &cfg, n)?;
    let warm_chip = ChipFaults::new(3, FaultRates::paper_default());
    let mut cold = CompileSession::builder(cfg).threads(1).chip(&warm_chip);
    let t_cold = Timer::start();
    let cold_out = cold.compile_model(&tensors);
    let cold_secs = t_cold.secs();
    let cache_path = std::env::temp_dir().join("rchg_bench_session.rcs");
    cold.save(&cache_path)?;
    let mut warm = CompileSession::load(&cache_path)?;
    let t_warm = Timer::start();
    let warm_out = warm.compile_model(&tensors);
    let warm_secs = t_warm.secs();
    std::fs::remove_file(&cache_path).ok();
    let cold_solves: usize = cold_out.iter().map(|(_, t, _)| t.stats.unique_pairs).sum();
    let warm_solves: usize = warm_out.iter().map(|(_, t, _)| t.stats.unique_pairs).sum();
    for ((_, a, _), (_, b, _)) in cold_out.iter().zip(&warm_out) {
        assert_eq!(a.decomps, b.decomps, "warm recompile diverged from cold");
        assert_eq!(a.errors, b.errors);
    }
    println!(
        "  cold: {} solves in {} — warm: {} solves in {} ({:.1}x faster)",
        cold_solves,
        fmt_dur(cold_secs),
        warm_solves,
        fmt_dur(warm_secs),
        cold_secs / warm_secs.max(1e-9),
    );
    println!(
        "  warm-start criterion (skip ≥90% of solves): {}",
        if warm_solves * 10 <= cold_solves { "PASS" } else { "FAIL" }
    );
    assert!(warm_solves * 10 <= cold_solves, "warm recompile must skip ≥90% of solves");

    // Tracing overhead criteria. Disabled path: a span call with no sink
    // installed is one relaxed atomic load — no allocation, no lock, no
    // clock read — and must stay in the low-nanosecond range. Enabled
    // path: a traced cold compile (spans come from the sequential batch
    // driver only) must stay within 5% of the untraced wall clock.
    println!("== obs tracing overhead");
    obs::set_sink(None);
    let calls: u64 = if quick { 1_000_000 } else { 10_000_000 };
    let t_noop = Timer::start();
    for _ in 0..calls {
        black_box(obs::span("bench.noop"));
    }
    let ns_per_call = t_noop.secs() * 1e9 / calls as f64;
    println!("  disabled span(): {ns_per_call:.2} ns/call over {calls} calls");
    assert!(ns_per_call < 1_000.0, "disabled-path span cost exploded: {ns_per_call:.0} ns/call");

    let cold_run = || {
        let mut s = CompileSession::builder(cfg).threads(1).chip(&warm_chip);
        let t = Timer::start();
        let out = s.compile_model(&tensors);
        (out, t.secs())
    };
    let (off_out, off_secs) = cold_run();
    let mem_sink = obs::MemorySink::new(1 << 16);
    obs::set_sink(Some(Box::new(mem_sink)));
    let (on_out, on_secs) = cold_run();
    let records = obs::set_sink(None);
    for ((_, a, _), (_, b, _)) in off_out.iter().zip(&on_out) {
        assert_eq!(a.decomps, b.decomps, "tracing changed a compiled bitmap");
        assert_eq!(a.errors, b.errors);
    }
    let overhead_pct = 100.0 * (on_secs - off_secs) / off_secs.max(1e-9);
    println!(
        "  untraced compile: {} — traced: {} ({records} records, {overhead_pct:+.2}% overhead)",
        fmt_dur(off_secs),
        fmt_dur(on_secs),
    );
    println!(
        "  enabled-path criterion (<5% compile overhead): {}",
        if overhead_pct < 5.0 { "PASS" } else { "FAIL" }
    );
    // The hard gate is looser than the printed criterion: single-shot
    // wall clocks on shared CI runners jitter more than 5% on their own.
    assert!(
        on_secs <= off_secs * 1.5 + 0.05,
        "traced compile overhead is pathological: {off_secs:.3}s -> {on_secs:.3}s"
    );
    Ok(())
}
