//! Table II / Fig 10 bench: compilation throughput of every method ×
//! config on real layer shapes. `cargo bench --bench bench_compile`.
//!
//! Full-model times for slow methods are extrapolated from deterministic
//! samples (printed explicitly). The complete pipeline additionally runs a
//! full-scale ResNet-20 compile (no sampling) as a ground-truth datapoint.

use rchg::coordinator::Method;
use rchg::experiments::compile_time::{fig10a, fig10b, measure, table2, CompileTimeOptions};
use rchg::grouping::GroupConfig;
use rchg::util::timer::fmt_dur;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let opts = CompileTimeOptions {
        models: if quick {
            vec!["resnet20".into()]
        } else {
            vec!["resnet20".into(), "resnet18".into(), "resnet50".into(), "vgg16".into()]
        },
        sample_complete: if quick { 50_000 } else { 400_000 },
        sample_ilp: if quick { 500 } else { 2_000 },
        sample_ff: if quick { 500 } else { 2_000 },
        threads: 1,
        include_r2c4: false,
    };

    let (t, rows) = table2(&opts)?;
    println!("{}", t.render());
    println!("{}", fig10a(&rows, &opts.models).render());
    println!("{}", fig10b(&rows, opts.models.last().unwrap()).render());

    // Ground-truth full-scale run: complete pipeline on all of ResNet-20.
    println!("== full-scale (no sampling) complete-pipeline runs");
    for cfg in [GroupConfig::R1C4, GroupConfig::R2C2] {
        let r = measure("resnet20", cfg, Method::Complete, usize::MAX, 1, 1)?;
        println!(
            "  resnet20 {} complete: {} for {} weights ({:.0} weights/s)",
            cfg.name(),
            fmt_dur(r.measured_secs),
            r.sampled_weights,
            r.sampled_weights as f64 / r.measured_secs
        );
    }
    Ok(())
}
