//! Table II / Fig 10 bench: compilation throughput of every method ×
//! config on real layer shapes. `cargo bench --bench bench_compile`.
//!
//! Full-model times for slow methods are extrapolated from deterministic
//! samples (printed explicitly). The complete pipeline additionally runs a
//! full-scale ResNet-20 compile (no sampling) as a ground-truth datapoint,
//! reports the pattern-class dedup factor (solver invocations vs weights),
//! and cross-checks that the dedupe-first core is byte-identical to the
//! legacy per-weight path at several thread counts.

use rchg::coordinator::{compile_tensor, CompileOptions, Method};
use rchg::experiments::compile_time::{
    dedup_report, fig10a, fig10b, measure, synthetic_model_weights, table2, CompileTimeOptions,
};
use rchg::fault::bank::ChipFaults;
use rchg::fault::FaultRates;
use rchg::grouping::GroupConfig;
use rchg::util::timer::fmt_dur;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let opts = CompileTimeOptions {
        models: if quick {
            vec!["resnet20".into()]
        } else {
            vec!["resnet20".into(), "resnet18".into(), "resnet50".into(), "vgg16".into()]
        },
        sample_complete: if quick { 50_000 } else { 400_000 },
        sample_ilp: if quick { 500 } else { 2_000 },
        sample_ff: if quick { 500 } else { 2_000 },
        threads: 1,
        include_r2c4: false,
    };

    let (t, rows) = table2(&opts)?;
    println!("{}", t.render());
    println!("{}", fig10a(&rows, &opts.models).render());
    println!("{}", fig10b(&rows, opts.models.last().unwrap()).render());
    println!("{}", dedup_report(&rows).render());

    // Ground-truth full-scale run: complete pipeline on all of ResNet-20,
    // with the dedup factor (weights per solver invocation) per config.
    println!("== full-scale (no sampling) complete-pipeline runs");
    let mut best_ratio = 1.0f64;
    for cfg in [GroupConfig::R1C4, GroupConfig::R2C2] {
        let r = measure("resnet20", cfg, Method::Complete, usize::MAX, 1, 1)?;
        println!(
            "  resnet20 {} complete: {} for {} weights ({:.0} weights/s) — \
             {} classes, {} unique pairs, {:.1}x dedup",
            cfg.name(),
            fmt_dur(r.measured_secs),
            r.sampled_weights,
            r.sampled_weights as f64 / r.measured_secs,
            r.unique_patterns,
            r.unique_pairs,
            r.dedup_ratio()
        );
        best_ratio = best_ratio.max(r.dedup_ratio());
    }
    println!(
        "  dedup criterion (solver on ≥5x fewer pairs than weights): {}",
        if best_ratio >= 5.0 { "PASS" } else { "FAIL" }
    );

    // Byte-equivalence: the pattern-class path must match the legacy
    // per-weight path exactly, at any thread count.
    println!("== pattern-class vs legacy per-weight equivalence (resnet20 sample)");
    let cfg = GroupConfig::R2C2;
    let n = if quick { 40_000 } else { 120_000 };
    let ws = synthetic_model_weights("resnet20", &cfg, n)?;
    let chip = ChipFaults::new(1, FaultRates::paper_default());
    let faults = chip.sample_tensor(0, ws.len(), cfg.cells());
    let mut legacy = CompileOptions::new(cfg, Method::Complete);
    legacy.dedupe = false;
    let base = compile_tensor(&ws, &faults, &legacy);
    for threads in [1usize, 4, 8] {
        let mut o = CompileOptions::new(cfg, Method::Complete);
        o.threads = threads;
        let out = compile_tensor(&ws, &faults, &o);
        assert_eq!(out.decomps, base.decomps, "decompositions diverged at threads={threads}");
        assert_eq!(out.errors, base.errors, "errors diverged at threads={threads}");
        println!(
            "  threads={threads}: byte-identical to legacy ({} weights, {} unique pairs, {})",
            ws.len(),
            out.stats.unique_pairs,
            fmt_dur(out.stats.wall_secs)
        );
    }
    Ok(())
}
