//! Per-stage decomposition microbenchmarks: how much does each pipeline
//! stage cost per weight, per grouping config? Feeds the §Perf analysis.

use rchg::baseline::fault_free::ff_decompose;
use rchg::coordinator::{decompose_one, Method, PipelineOptions};
use rchg::decompose::{cvm_ilp, fawd_ilp, GroupTables};
use rchg::experiments::bench::{seeded_cases, BENCH_CASE_POOL};
use rchg::grouping::{FaultAnalysis, GroupConfig};
use rchg::ilp::IlpStats;
use rchg::util::timer::{bench, bench_header, black_box};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let iters = if quick { 20 } else { 100 };
    println!("{}", bench_header());
    let mut difftable_speedup = f64::INFINITY;

    for cfg in [GroupConfig::R1C4, GroupConfig::R2C2, GroupConfig::R2C4] {
        // The seeded case pool shared with `rchg bench` (experiments::bench)
        // — the harness and this microbench measure the same inputs.
        let cases = seeded_cases(&cfg, BENCH_CASE_POOL);
        let mut st = IlpStats::default();
        let opts = PipelineOptions { method: Method::Complete, ..Default::default() };

        let mut i1 = 0usize;
        let stats = bench(&format!("{}/analysis", cfg.name()), iters, 0.2, || {
            i1 = (i1 + 1) % cases.len();
            let (f, _) = &cases[i1];
            black_box(FaultAnalysis::new(&cfg, f));
        });
        println!("{}", stats.report());

        let mut i2 = 0usize;
        let stats = bench(&format!("{}/complete-pipeline", cfg.name()), iters, 0.2, || {
            i2 = (i2 + 1) % cases.len();
            let (f, w) = &cases[i2];
            black_box(decompose_one(&cfg, f, *w, &opts, &mut st));
        });
        println!("{}", stats.report());

        let mut i3 = 0usize;
        let stats = bench(&format!("{}/table-build+cvm", cfg.name()), iters, 0.2, || {
            i3 = (i3 + 1) % cases.len();
            let (f, w) = &cases[i3];
            let t = GroupTables::build(&cfg, f);
            black_box(t.cvm(&cfg, f, *w));
        });
        println!("{}", stats.report());

        // DiffTable construction: vectorized builder vs the scalar
        // reference, same prebuilt GroupTables pool. The ≥1.5x criterion
        // is asserted after the config loop.
        let pool_n = if quick { 256 } else { 1024 };
        let tables: Vec<GroupTables> =
            cases.iter().take(pool_n).map(|(f, _)| GroupTables::build(&cfg, f)).collect();
        for gt in &tables {
            assert_eq!(
                gt.diff_table(),
                gt.diff_table_reference(),
                "vectorized DiffTable diverged from reference ({})",
                cfg.name()
            );
        }
        let fast = bench(&format!("{}/difftable-build", cfg.name()), iters, 0.2, || {
            for gt in &tables {
                black_box(gt.diff_table());
            }
        });
        println!("{}", fast.report());
        let reference =
            bench(&format!("{}/difftable-build-reference", cfg.name()), iters, 0.2, || {
                for gt in &tables {
                    black_box(gt.diff_table_reference());
                }
            });
        println!("{}", reference.report());
        let speedup = reference.mean_s / fast.mean_s.max(1e-12);
        println!("  {} difftable speedup: {:.2}x", cfg.name(), speedup);
        difftable_speedup = difftable_speedup.min(speedup);

        let mut i4 = 0usize;
        let stats = bench(&format!("{}/ilp-fawd", cfg.name()), iters.min(30), 0.1, || {
            i4 = (i4 + 1) % cases.len();
            let (f, w) = &cases[i4];
            black_box(fawd_ilp(&cfg, f, *w, &mut st));
        });
        println!("{}", stats.report());

        let mut i5 = 0usize;
        let stats = bench(&format!("{}/ilp-cvm", cfg.name()), iters.min(30), 0.1, || {
            i5 = (i5 + 1) % cases.len();
            let (f, w) = &cases[i5];
            black_box(cvm_ilp(&cfg, f, *w, &mut st));
        });
        println!("{}", stats.report());

        if cfg.rows == 1 {
            let mut i6 = 0usize;
            let stats = bench(&format!("{}/original-ff", cfg.name()), iters.min(20), 0.1, || {
                i6 = (i6 + 1) % cases.len();
                let (f, w) = &cases[i6];
                black_box(ff_decompose(&cfg, f, *w));
            });
            println!("{}", stats.report());
        }
    }

    println!(
        "difftable criterion (vectorized ≥1.5x reference on every config): {} \
         (worst {difftable_speedup:.2}x)",
        if difftable_speedup >= 1.5 { "PASS" } else { "FAIL" }
    );
    assert!(
        difftable_speedup >= 1.5,
        "vectorized DiffTable build must be ≥1.5x the scalar reference \
         (worst config: {difftable_speedup:.2}x)"
    );
}
