//! Per-stage decomposition microbenchmarks: how much does each pipeline
//! stage cost per weight, per grouping config? Feeds the §Perf analysis.

use rchg::baseline::fault_free::ff_decompose;
use rchg::coordinator::{decompose_one, Method, PipelineOptions};
use rchg::decompose::{cvm_ilp, fawd_ilp, GroupTables};
use rchg::fault::{FaultRates, GroupFaults};
use rchg::grouping::{FaultAnalysis, GroupConfig};
use rchg::ilp::IlpStats;
use rchg::util::prng::Rng;
use rchg::util::timer::{bench, bench_header, black_box};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let iters = if quick { 20 } else { 100 };
    println!("{}", bench_header());

    for cfg in [GroupConfig::R1C4, GroupConfig::R2C2, GroupConfig::R2C4] {
        let rates = FaultRates::paper_default();
        // Pre-sample a pool of cases so the RNG isn't in the timed path.
        let mut rng = Rng::new(7);
        let cases: Vec<(GroupFaults, i64)> = (0..4096)
            .map(|_| {
                (
                    GroupFaults::sample(cfg.cells(), &rates, &mut rng),
                    rng.range_i64(-cfg.max_per_array(), cfg.max_per_array()),
                )
            })
            .collect();
        let mut st = IlpStats::default();
        let opts = PipelineOptions { method: Method::Complete, ..Default::default() };

        let mut i1 = 0usize;
        let stats = bench(&format!("{}/analysis", cfg.name()), iters, 0.2, || {
            i1 = (i1 + 1) % cases.len();
            let (f, _) = &cases[i1];
            black_box(FaultAnalysis::new(&cfg, f));
        });
        println!("{}", stats.report());

        let mut i2 = 0usize;
        let stats = bench(&format!("{}/complete-pipeline", cfg.name()), iters, 0.2, || {
            i2 = (i2 + 1) % cases.len();
            let (f, w) = &cases[i2];
            black_box(decompose_one(&cfg, f, *w, &opts, &mut st));
        });
        println!("{}", stats.report());

        let mut i3 = 0usize;
        let stats = bench(&format!("{}/table-build+cvm", cfg.name()), iters, 0.2, || {
            i3 = (i3 + 1) % cases.len();
            let (f, w) = &cases[i3];
            let t = GroupTables::build(&cfg, f);
            black_box(t.cvm(&cfg, f, *w));
        });
        println!("{}", stats.report());

        let mut i4 = 0usize;
        let stats = bench(&format!("{}/ilp-fawd", cfg.name()), iters.min(30), 0.1, || {
            i4 = (i4 + 1) % cases.len();
            let (f, w) = &cases[i4];
            black_box(fawd_ilp(&cfg, f, *w, &mut st));
        });
        println!("{}", stats.report());

        let mut i5 = 0usize;
        let stats = bench(&format!("{}/ilp-cvm", cfg.name()), iters.min(30), 0.1, || {
            i5 = (i5 + 1) % cases.len();
            let (f, w) = &cases[i5];
            black_box(cvm_ilp(&cfg, f, *w, &mut st));
        });
        println!("{}", stats.report());

        if cfg.rows == 1 {
            let mut i6 = 0usize;
            let stats = bench(&format!("{}/original-ff", cfg.name()), iters.min(20), 0.1, || {
                i6 = (i6 + 1) % cases.len();
                let (f, w) = &cases[i6];
                black_box(ff_decompose(&cfg, f, *w));
            });
            println!("{}", stats.report());
        }
    }
}
