//! Quickstart: the paper's story on a single weight, then one crossbar
//! layer end-to-end through the AOT runtime.
//!
//!   cargo run --release --example quickstart
//!
//! Walks Fig 1/Fig 3: a stuck-at fault distorts a stored weight; the
//! compilation pipeline finds an alternative decomposition that masks it;
//! hybrid grouping makes masking easier. Then loads the AOT-compiled
//! `imc_linear_r2c2` artifact (Pallas kernel inside) and runs a faulty
//! crossbar MVM whose outputs match the mitigated weights exactly.

use rchg::coordinator::{decompose_one, CompileSession, Method, PipelineOptions};
use rchg::fault::bank::ChipFaults;
use rchg::fault::{FaultRates, FaultState, GroupFaults};
use rchg::grouping::{Decomposition, GroupConfig};
use rchg::ilp::IlpStats;
use rchg::nn::packing::Planes;
use rchg::runtime::{artifacts_dir, ArgValue, Runtime};
use rchg::util::prng::Rng;

fn main() -> anyhow::Result<()> {
    println!("=== 1. A stuck-at fault distorts a weight (Fig 1b) ===");
    let cfg = GroupConfig::R1C4;
    let w = 52i64;
    let d = Decomposition::encode_ideal(w, &cfg);
    println!("weight {w} encodes to cells {:?} (R1C4, L=4)", d.pos.cells);
    let mut faults = GroupFaults::free(cfg.cells());
    faults.pos[0] = FaultState::Sa0; // MSB stuck at high conductance
    faults.pos[2] = FaultState::Sa1; // 2nd LSB stuck at zero
    println!(
        "with SA0@MSB + SA1@2ndLSB the array reads {} — catastrophic",
        d.faulty_value(&cfg, &faults)
    );

    println!("\n=== 2. The pipeline masks it (Fig 3 / Fig 7) ===");
    let mut st = IlpStats::default();
    let opts = PipelineOptions { method: Method::Complete, ..Default::default() };
    let out = decompose_one(&cfg, &faults, w, &opts, &mut st);
    println!(
        "complete pipeline → stage {:?}, cells pos={:?} neg={:?}, reads {} (error {})",
        out.stage,
        out.decomposition.pos.cells,
        out.decomposition.neg.cells,
        out.decomposition.faulty_value(&cfg, &faults),
        out.error
    );

    println!("\n=== 3. Hybrid grouping adds redundancy (Fig 5) ===");
    for cfg in [GroupConfig::R1C4, GroupConfig::R2C2, GroupConfig::R2C4] {
        let mut rng = Rng::new(7);
        let rates = FaultRates::paper_default();
        let n = 20_000;
        let mut imperfect = 0;
        let mut total_err = 0i64;
        for _ in 0..n {
            let f = GroupFaults::sample(cfg.cells(), &rates, &mut rng);
            let w = rng.range_i64(-cfg.max_per_array(), cfg.max_per_array());
            let o = decompose_one(&cfg, &f, w, &opts, &mut st);
            if o.error != 0 {
                imperfect += 1;
                total_err += o.error;
            }
        }
        let mean_err = total_err as f64 / imperfect.max(1) as f64;
        println!(
            "{:<5} ({:.2}-bit): {:>6.3}% of weights keep residual error, \
             mean |err| {:.2} LSB = {:.1}% of range",
            cfg.name(),
            cfg.precision_bits(),
            100.0 * imperfect as f64 / n as f64,
            mean_err,
            100.0 * mean_err / cfg.max_per_array() as f64,
        );
    }

    println!("\n=== 4. A chip-scoped CompileSession (dedupe-first, warm-startable) ===");
    // The compiler's entry point is a session bound to one chip. It does
    // not solve weight-by-weight: it interns each group's fault pattern,
    // dedupes to unique (pattern, weight) pairs, solves each pair once,
    // and scatters the results back — most weights are cache hits because
    // realistic SAF rates produce few distinct patterns. The session owns
    // that cache, so every tensor of the chip (and every later model
    // revision) reuses everything solved before.
    //
    // The old free functions (compile_tensor / compile_tensor_with_cache /
    // compile_model) are gone — sessions are the only compile surface:
    //   compile_tensor(ws, faults, opts)      → session.compile_with_faults(ws, faults)
    //   compile_tensor_with_cache(…, cache)   → same (the session owns the cache)
    //   compile_model(tensors, chip, opts)    → session.compile_model(tensors)
    // Under the hood the session now solves each fault pattern ONCE for
    // its whole weight range (a dense per-pattern table, bounded by an
    // LRU memory budget) instead of once per (pattern, weight) pair.
    let cfg = GroupConfig::R2C2;
    let chip = ChipFaults::new(7, FaultRates::paper_default());
    let mut session =
        CompileSession::builder(cfg).method(Method::Complete).threads(1).chip(&chip);
    let mut rng = Rng::new(1);
    let n = 30_000;
    let ws: Vec<i64> =
        (0..n).map(|_| rng.range_i64(-cfg.max_per_array(), cfg.max_per_array())).collect();
    let compiled = session.compile_tensor("conv1", &ws);
    println!(
        "compiled {n} weights via {} pattern classes and {} unique (pattern, weight) \
         pairs — {:.1}x dedup, {} tables built",
        compiled.stats.unique_patterns,
        compiled.stats.unique_pairs,
        compiled.stats.dedup_ratio(),
        compiled.stats.tables_built,
    );

    // Persist the warm state and recompile: the chip's fault pattern is
    // fixed, so a reloaded session solves nothing for an unchanged tensor.
    let cache_path = std::env::temp_dir().join("rchg_quickstart_session.rcs");
    session.save(&cache_path)?;
    let mut warm = CompileSession::load(&cache_path)?;
    let again = warm.compile_tensor("conv1", &ws);
    println!(
        "warm recompile after save/load: {} fresh solves, {} cache hits — byte-identical: {}",
        again.stats.unique_pairs,
        again.stats.dedup_hits,
        again.decomps == compiled.decomps,
    );
    std::fs::remove_file(&cache_path).ok();

    println!("\n=== 5. End-to-end through the AOT crossbar kernel ===");
    let art = artifacts_dir();
    if !art.join("manifest.json").exists() {
        println!("artifacts not built — run `make artifacts` first to see the runtime demo");
        return Ok(());
    }
    let rt = Runtime::new(&art)?;
    println!("PJRT platform: {}", rt.platform());
    let cfg = GroupConfig::R2C2;
    let exe = rt.load("imc_linear_r2c2")?;
    let (k, n) = (64usize, 10usize);
    let mut rng = Rng::new(42);
    let rates = FaultRates::paper_default();

    // Quantized weights + per-weight faults → mitigated decompositions.
    let ws: Vec<i64> = (0..k * n).map(|_| rng.range_i64(-30, 30)).collect();
    let faults: Vec<GroupFaults> =
        (0..k * n).map(|_| GroupFaults::sample(cfg.cells(), &rates, &mut rng)).collect();
    let decomps: Vec<Decomposition> = ws
        .iter()
        .zip(&faults)
        .map(|(&w, f)| decompose_one(&cfg, f, w, &opts, &mut st).decomposition)
        .collect();
    let planes = Planes::pack(&decomps, Some(&faults), k, n, &cfg);

    let x: Vec<f32> = (0..8 * k).map(|_| rng.normal_f32()).collect();
    let sigs: Vec<f32> = cfg.significances().iter().map(|&s| s as f32).collect();
    let out = exe.run(&[
        ArgValue::F32(&x),
        ArgValue::F32(&planes.pos),
        ArgValue::F32(&planes.neg),
        ArgValue::F32(&sigs),
    ])?;

    // Reference: x @ w̃ where w̃ is the mitigated faulty weight.
    let w_eff: Vec<i64> = decomps
        .iter()
        .zip(&faults)
        .map(|(d, f)| d.faulty_value(&cfg, f))
        .collect();
    let mut max_err = 0f32;
    let mut max_mitig_err = 0i64;
    for b in 0..8 {
        for j in 0..n {
            let want: f32 = (0..k).map(|i| x[b * k + i] * w_eff[i * n + j] as f32).sum();
            max_err = max_err.max((want - out[b * n + j]).abs());
        }
    }
    for (w, we) in ws.iter().zip(&w_eff) {
        max_mitig_err = max_mitig_err.max((w - we).abs());
    }
    println!(
        "ran imc_linear_r2c2 on a faulty chip: kernel-vs-reference max |err| = {max_err:.2e}, \
         max residual weight error after mitigation = {max_mitig_err} LSB"
    );
    println!("quickstart OK");
    Ok(())
}
