//! Fig 6: Monte-Carlo probability of inconsecutivity errors per grouping
//! configuration at the published fault rates (SA0 1.75%, SA1 9.04%).
//!
//!   cargo run --release --example inconsecutivity
//!   cargo run --release --example inconsecutivity -- --samples 2000000

use rchg::experiments::hw::fig6;
use rchg::grouping::GroupConfig;
use rchg::util::cli::Cli;

fn main() {
    let cli = Cli::new("inconsecutivity probability (Fig 6)")
        .opt("samples", "Monte-Carlo samples per config", Some("1000000"))
        .opt("configs", "grouping configs", Some("r1c4,r2c2,r2c4"))
        .opt("seed", "rng seed", Some("99"));
    let args = cli.parse(std::env::args());
    let configs: Vec<GroupConfig> = args
        .get_list("configs")
        .iter()
        .filter_map(|s| GroupConfig::parse(s))
        .collect();
    let t = fig6(&configs, args.get_usize("samples", 1_000_000), args.get_u64("seed", 99));
    println!("{}", t.render());
    println!(
        "(paper reports R1C4 = 3.49%, R2C2 = 0.01% — the two-orders-of-magnitude gap\n\
         is the claim; see DESIGN.md §5 acceptance criteria)"
    );
}
