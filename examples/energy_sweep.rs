//! Fig 11: normalized energy consumption vs crossbar array size, for the
//! hybrid grouping configurations against the R1C4 column-grouping
//! baseline (NeuroSIM/ConvMapSIM-style model, kernel-splitting mapper).
//!
//!   cargo run --release --example energy_sweep
//!   cargo run --release --example energy_sweep -- --model resnet18
//!   cargo run --release --example energy_sweep -- --packed   # ablation mapper

use rchg::arrays::MapperPolicy;
use rchg::energy::EnergyParams;
use rchg::experiments::hw::fig11;
use rchg::util::cli::Cli;

fn main() -> anyhow::Result<()> {
    let cli = Cli::new("energy vs array size (Fig 11)")
        .opt("model", "network (resnet20|resnet18|resnet50|vgg16)", Some("resnet20"))
        .opt("sizes", "array sizes", Some("64,128,256,512"))
        .opt("packed", "use the utilization-packed mapper (ablation)", None)
        .opt("adc-energy", "ADC energy per conversion (pJ)", Some("2.0"));
    let args = cli.parse(std::env::args());

    let sizes: Vec<usize> =
        args.get_list("sizes").iter().filter_map(|s| s.parse().ok()).collect();
    let policy = if args.get_bool("packed") {
        MapperPolicy::PackedVertical
    } else {
        MapperPolicy::KernelSplit
    };
    let mut params = EnergyParams::default();
    params.e_adc = args.get_f64("adc-energy", 2.0);

    for model in [args.get_str("model", "resnet20").to_string(), "resnet18".to_string()] {
        let t = fig11(&model, &sizes, &params, policy)?;
        println!("{}", t.render());
        if args.get_str("model", "resnet20") != "resnet20" {
            break; // explicit model given: print only that one
        }
    }
    Ok(())
}
