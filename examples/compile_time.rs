//! Table II + Fig 10: compilation-time evaluation of the proposed pipeline
//! against the original Fault-Free baseline and the ILP-only variant.
//!
//!   cargo run --release --example compile_time
//!   cargo run --release --example compile_time -- --models resnet20
//!   cargo run --release --example compile_time -- --full-complete  # no sampling
//!   cargo run --release --example compile_time -- --r2c4           # ILP-FAWD config

use rchg::experiments::compile_time::{fig10a, fig10b, table2, CompileTimeOptions};
use rchg::util::cli::Cli;

fn main() -> anyhow::Result<()> {
    let cli = Cli::new("compilation time (Table II / Fig 10)")
        .opt("models", "models to compile", Some("resnet20,resnet18,resnet50,vgg16"))
        .opt("sample-complete", "weight sample for complete pipeline", Some("400000"))
        .opt("sample-ilp", "weight sample for ILP-only", Some("2000"))
        .opt("sample-ff", "weight sample for original FF", Some("2000"))
        .opt("threads", "compile threads (paper: 1)", Some("1"))
        .opt("full-complete", "run the complete pipeline at full model scale", None)
        .opt("r2c4", "include the R2C4 row (ILP-FAWD territory)", None)
        .opt("breakdown-model", "model for the Fig 10b breakdown", Some("resnet18"));
    let args = cli.parse(std::env::args());

    let opts = CompileTimeOptions {
        models: args.get_list("models"),
        sample_complete: if args.get_bool("full-complete") {
            usize::MAX
        } else {
            args.get_usize("sample-complete", 400_000)
        },
        sample_ilp: args.get_usize("sample-ilp", 2_000),
        sample_ff: args.get_usize("sample-ff", 2_000),
        threads: args.get_usize("threads", 1),
        include_r2c4: args.get_bool("r2c4"),
    };

    let (t, rows) = table2(&opts)?;
    println!("{}", t.render());
    println!("{}", fig10a(&rows, &opts.models).render());
    println!("{}", fig10b(&rows, args.get_str("breakdown-model", "resnet18")).render());
    Ok(())
}
