//! Table III: OPT-like LM perplexity under stuck-at faults.
//!
//!   cargo run --release --example lm_perplexity
//!   cargo run --release --example lm_perplexity -- --trials 10 --windows 120
//!   cargo run --release --example lm_perplexity -- --unprotected

use rchg::experiments::lm::{table3, LmOptions};
use rchg::grouping::GroupConfig;
use rchg::runtime::{artifacts_dir, Runtime};
use rchg::util::cli::Cli;

fn main() -> anyhow::Result<()> {
    let cli = Cli::new("LM perplexity under SAFs (Table III)")
        .opt("configs", "grouping configs", Some("r1c4,r2c2"))
        .opt("trials", "chips per config", Some("3"))
        .opt("windows", "eval windows per stream", Some("60"))
        .opt("threads", "compile threads", Some("1"))
        .opt("unprotected", "add no-mitigation rows", None);
    let args = cli.parse(std::env::args());

    let art = artifacts_dir();
    let rt = Runtime::new(&art)?;
    let opts = LmOptions {
        configs: args
            .get_list("configs")
            .iter()
            .filter_map(|s| GroupConfig::parse(s))
            .collect(),
        trials: args.get_usize("trials", 3),
        threads: args.get_usize("threads", 1),
        max_windows: args.get_usize("windows", 60),
        include_unprotected: args.get_bool("unprotected"),
    };
    let t = table3(&rt, &art, &opts)?;
    println!("{}", t.render());
    Ok(())
}
