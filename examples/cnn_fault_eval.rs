//! End-to-end driver (Table I / Fig 8 / Fig 9): CNN accuracy under
//! stuck-at faults across grouping configurations, run through the full
//! three-layer stack — rust coordinator compiles per-chip decompositions,
//! the PJRT runtime executes the AOT model graphs (Pallas FC head inside).
//!
//!   cargo run --release --example cnn_fault_eval                 # Table I
//!   cargo run --release --example cnn_fault_eval -- --layerwise  # + Fig 8
//!   cargo run --release --example cnn_fault_eval -- --sweep      # + Fig 9
//!   cargo run --release --example cnn_fault_eval -- --trials 5 --archs cnn_s
//!   cargo run --release --example cnn_fault_eval -- --unprotected

use rchg::experiments::accuracy::{fig8, fig9, table1, AccuracyOptions};
use rchg::grouping::GroupConfig;
use rchg::runtime::{artifacts_dir, Runtime};
use rchg::util::cli::Cli;

fn main() -> anyhow::Result<()> {
    let cli = Cli::new("CNN fault-injection accuracy (Table I / Fig 8 / Fig 9)")
        .opt("archs", "comma-separated architectures", Some("cnn_s,cnn_m,cnn_d,vgg_n"))
        .opt("configs", "grouping configs", Some("r1c4,r2c2,r2c4"))
        .opt("trials", "chips (fault maps) per cell", Some("3"))
        .opt("threads", "compile threads", Some("1"))
        .opt("layerwise", "also print Fig 8 layer-wise error", None)
        .opt("sweep", "also print Fig 9 fault-rate sweep", None)
        .opt("unprotected", "add no-mitigation rows", None)
        .opt("sweep-arch", "architecture for the sweep", Some("cnn_s"));
    let args = cli.parse(std::env::args());

    let art = artifacts_dir();
    let rt = Runtime::new(&art)?;
    let opts = AccuracyOptions {
        archs: args.get_list("archs"),
        configs: args
            .get_list("configs")
            .iter()
            .filter_map(|s| GroupConfig::parse(s))
            .collect(),
        trials: args.get_usize("trials", 3),
        threads: args.get_usize("threads", 1),
        include_unprotected: args.get_bool("unprotected"),
    };

    let t = table1(&rt, &art, &opts)?;
    println!("{}", t.render());

    if args.get_bool("layerwise") {
        let t = fig8(&rt, &art, args.get_str("sweep-arch", "cnn_s"), opts.threads)?;
        println!("{}", t.render());
    }

    if args.get_bool("sweep") {
        let rates = [0.02, 0.05, 0.1079, 0.15, 0.20];
        let t = fig9(
            &rt,
            &art,
            args.get_str("sweep-arch", "cnn_s"),
            &rates,
            opts.trials.min(3),
            opts.threads,
        )?;
        println!("{}", t.render());
    }
    Ok(())
}
