"""L2 model graph tests: shapes, float/deploy consistency, loss sanity."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import model as M
from compile import packing


@pytest.mark.parametrize("arch", list(M.CNN_ARCHS))
def test_cnn_shapes(arch):
    key = jax.random.PRNGKey(0)
    params = M.cnn_init(arch, key)
    x = jnp.zeros((2, 32, 32, 3), jnp.float32)
    logits = M.cnn_forward_float(params, x, arch)
    assert logits.shape == (2, M.NUM_CLASSES)


@pytest.mark.parametrize("r,c,levels", [(1, 4, 4), (2, 2, 4)])
def test_cnn_deploy_matches_float_with_ideal_planes(r, c, levels):
    """With fault-free planes packed from the quantized FC weights, the
    deploy graph must equal the float graph with quantized FC."""
    arch = "cnn_s"
    key = jax.random.PRNGKey(1)
    params = M.cnn_init(arch, key)
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 32, 32, 3), jnp.float32)

    fc_w = np.asarray(params["fc_w"])
    max_int = r * (levels**c - 1)
    w_int, scale = packing.quantize_sym(fc_w, max_int)
    pos, neg = packing.pack_planes(w_int, r, c, levels)
    s = packing.sigs(c, levels)

    conv = {k: v for k, v in params.items() if k.startswith("conv")}
    deploy = M.cnn_forward_deploy(
        conv, x, pos, neg, s, scale, params["fc_b"], arch=arch, rows=r
    )

    params_q = dict(params)
    params_q["fc_w"] = jnp.asarray(w_int.astype(np.float32) * scale)
    ref = M.cnn_forward_float(params_q, x, arch)
    np.testing.assert_allclose(np.asarray(deploy), np.asarray(ref), rtol=1e-4, atol=1e-4)


def test_lm_shapes_and_causality():
    params = M.lm_init(jax.random.PRNGKey(0))
    toks = jnp.zeros((2, 16), jnp.int32)
    logits = M.lm_forward_float(params, toks)
    assert logits.shape == (2, 16, M.LM_CONFIG["vocab"])
    # Causality: changing a future token must not affect earlier logits.
    toks2 = toks.at[:, 10].set(65)
    logits2 = M.lm_forward_float(params, toks2)
    np.testing.assert_allclose(
        np.asarray(logits[:, :10]), np.asarray(logits2[:, :10]), atol=1e-5
    )
    assert not np.allclose(np.asarray(logits[:, 10:]), np.asarray(logits2[:, 10:]))


def test_lm_deploy_matches_float_with_ideal_planes():
    r, c, levels = 2, 2, 4
    params = M.lm_init(jax.random.PRNGKey(3))
    toks = jax.random.randint(jax.random.PRNGKey(4), (2, 12), 0, 255)

    head_w = np.asarray(params["embed"]).T  # [d, vocab]
    max_int = r * (levels**c - 1)
    w_int, scale = packing.quantize_sym(head_w, max_int)
    pos, neg = packing.pack_planes(w_int, r, c, levels)
    s = packing.sigs(c, levels)

    deploy = M.lm_forward_deploy(params, toks, pos, neg, s, scale, rows=r)

    h = M.lm_trunk(params, toks)
    ref = h @ jnp.asarray(w_int.astype(np.float32) * scale)
    np.testing.assert_allclose(np.asarray(deploy), np.asarray(ref), rtol=1e-3, atol=1e-3)


def test_cnn_training_reduces_loss():
    from compile import data as D

    arch = "cnn_s"
    x, y = D.synth_cifar(256, seed=5)
    x, y = jnp.asarray(x), jnp.asarray(y)
    params = M.cnn_init(arch, jax.random.PRNGKey(7))
    opt = M.adam_init(params)
    step = M.make_cnn_train_step(arch, lr=2e-3)
    first = None
    loss = None
    for i in range(30):
        params, opt, loss = step(params, opt, x[:64], y[:64])
        if first is None:
            first = float(loss)
    assert float(loss) < first * 0.8, f"loss {first} -> {float(loss)}"


def test_lm_training_reduces_loss():
    params = M.lm_init(jax.random.PRNGKey(11))
    opt = M.adam_init(params)
    step = M.make_lm_train_step(lr=1e-3)
    rng = np.random.default_rng(0)
    # Learnable synthetic stream: repeated ascii phrase.
    phrase = np.frombuffer(b"the quick brown fox jumps over the lazy dog. " * 200, dtype=np.uint8)
    toks = phrase.astype(np.int32)
    from compile import data as D

    first = None
    loss = None
    for i in range(25):
        batch = D.batch_tokens(toks, 4, 48, rng)
        params, opt, loss = step(params, opt, jnp.asarray(batch))
        if first is None:
            first = float(loss)
    assert float(loss) < first * 0.7, f"loss {first} -> {float(loss)}"


def test_adam_updates_all_leaves():
    params = {"a": jnp.ones((3,)), "b": jnp.zeros((2, 2))}
    opt = M.adam_init(params)
    grads = {"a": jnp.ones((3,)), "b": jnp.ones((2, 2))}
    new, opt = M.adam_update(params, grads, opt, lr=0.1)
    assert not np.allclose(np.asarray(new["a"]), np.asarray(params["a"]))
    assert not np.allclose(np.asarray(new["b"]), np.asarray(params["b"]))
    assert opt["t"] == 1
