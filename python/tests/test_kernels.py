"""L1 kernel correctness: Pallas crossbar MVM vs pure-jnp oracle.

Hypothesis sweeps shapes, grouping configs and dtypes; every case asserts
allclose between the interpret-mode Pallas kernel and ref.py.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels.crossbar_mvm import fault_inject, imc_linear, imc_matmul
from compile.kernels import ref


def rand_case(rng, b, k, n, c, r, levels):
    x = rng.normal(size=(b, k)).astype(np.float32)
    pos = rng.integers(0, levels, size=(c, k * r, n)).astype(np.float32)
    neg = rng.integers(0, levels, size=(c, k * r, n)).astype(np.float32)
    s = np.array([float(levels ** (c - 1 - j)) for j in range(c)], np.float32)
    return x, pos, neg, s


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 9),
    k=st.integers(1, 33),
    n=st.integers(1, 17),
    c=st.integers(1, 4),
    r=st.integers(1, 3),
    levels=st.sampled_from([2, 4]),
    seed=st.integers(0, 2**16),
)
def test_imc_linear_matches_ref(b, k, n, c, r, levels, seed):
    rng = np.random.default_rng(seed)
    x, pos, neg, s = rand_case(rng, b, k, n, c, r, levels)
    got = imc_linear(x, pos, neg, s, rows_per_weight=r)
    want = ref.imc_linear_ref(x, pos, neg, s, rows_per_weight=r)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 6),
    k=st.integers(2, 24),
    n=st.integers(2, 12),
    adc_bits=st.sampled_from([4, 6, 8]),
    seed=st.integers(0, 2**16),
)
def test_adc_mode_matches_ref(b, k, n, adc_bits, seed):
    rng = np.random.default_rng(seed)
    x, pos, neg, s = rand_case(rng, b, k, n, 2, 2, 4)
    got = imc_linear(x, pos, neg, s, rows_per_weight=2, adc_bits=adc_bits)
    want = ref.imc_linear_ref(x, pos, neg, s, rows_per_weight=2, adc_bits=adc_bits)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)


def test_blocked_grid_path():
    """Shapes larger than one 128-block exercise the multi-step grid."""
    rng = np.random.default_rng(0)
    x, pos, neg, s = rand_case(rng, 130, 40, 150, 2, 2, 4)
    got = imc_matmul(jnp.repeat(jnp.asarray(x), 2, axis=1), pos, neg, s)
    want = ref.imc_linear_ref(x, pos, neg, s, rows_per_weight=2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-3)


def test_explicit_small_example():
    """Hand-checked example: single weight 19 in R1C4, identity input."""
    # w=19 → digits (0,1,0,3) base-4 MSB-first.
    pos = np.zeros((4, 1, 1), np.float32)
    pos[1, 0, 0], pos[3, 0, 0] = 1.0, 3.0
    neg = np.zeros((4, 1, 1), np.float32)
    s = np.array([64.0, 16.0, 4.0, 1.0], np.float32)
    x = np.ones((1, 1), np.float32)
    out = imc_linear(x, pos, neg, s, rows_per_weight=1)
    assert float(out[0, 0]) == 19.0


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 16),
    n=st.integers(1, 16),
    levels=st.sampled_from([2, 4]),
    seed=st.integers(0, 2**16),
)
def test_fault_inject_matches_eq1(m, n, levels, seed):
    rng = np.random.default_rng(seed)
    x = rng.integers(0, levels, size=(m, n)).astype(np.float32)
    f0 = (rng.random((m, n)) < 0.2).astype(np.float32)
    f1 = ((rng.random((m, n)) < 0.2) * (1 - f0)).astype(np.float32)
    got = fault_inject(x, f0, f1, levels)
    want = ref.fault_inject_ref(x, f0, f1, float(levels))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # SA0 cells read L-1, SA1 cells read 0, free cells unchanged.
    got_np = np.asarray(got)
    assert (got_np[f0 == 1] == levels - 1).all()
    assert (got_np[f1 == 1] == 0).all()
    free = (f0 == 0) & (f1 == 0)
    assert (got_np[free] == x[free]).all()


def test_reconstructed_weight_identity():
    """Kernel on identity input == collapsed logical weight matrix."""
    rng = np.random.default_rng(3)
    k, n, c, r, levels = 6, 5, 2, 2, 4
    pos = rng.integers(0, levels, size=(c, k * r, n)).astype(np.float32)
    neg = rng.integers(0, levels, size=(c, k * r, n)).astype(np.float32)
    s = np.array([4.0, 1.0], np.float32)
    w_eff = ref.reconstructed_weight_ref(pos, neg, s, rows_per_weight=r)
    out = imc_linear(np.eye(k, dtype=np.float32), pos, neg, s, rows_per_weight=r)
    np.testing.assert_allclose(np.asarray(out), np.asarray(w_eff), atol=1e-4)


@pytest.mark.parametrize("r,c,levels", [(1, 4, 4), (2, 2, 4), (2, 4, 4)])
def test_packed_planes_reproduce_weights(r, c, levels):
    """packing.pack_planes ∘ imc_linear == integer weight matmul."""
    from compile import packing

    rng = np.random.default_rng(11)
    k, n = 5, 4
    max_int = r * (levels**c - 1)
    w_int = rng.integers(-max_int, max_int + 1, size=(k, n))
    pos, neg = packing.pack_planes(w_int, r, c, levels)
    s = packing.sigs(c, levels)
    x = rng.normal(size=(3, k)).astype(np.float32)
    got = imc_linear(x, pos, neg, s, rows_per_weight=r)
    want = x @ w_int.astype(np.float32)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-3)
