"""AOT export + dataset tests: manifest consistency, .bin format
round-trip (against the rust reader's layout), corpus determinism."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from compile import data as D

ART = os.environ.get(
    "RCHG_ARTIFACTS",
    os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"),
)


def test_bin_roundtrip_f32():
    arr = np.arange(12, dtype=np.float32).reshape(3, 4) * -1.5
    p = "/tmp/rchg_test_f32.bin"
    D.save_bin(p, arr)
    out = D.load_bin(p)
    np.testing.assert_array_equal(arr, out)


def test_bin_roundtrip_i32_u8():
    arr = np.array([-5, 0, 2**30], dtype=np.int32)
    p = "/tmp/rchg_test_i32.bin"
    D.save_bin(p, arr)
    np.testing.assert_array_equal(D.load_bin(p), arr)
    b = np.array([[0, 255], [7, 8]], dtype=np.uint8)
    D.save_bin("/tmp/rchg_test_u8.bin", b)
    np.testing.assert_array_equal(D.load_bin("/tmp/rchg_test_u8.bin"), b)


def test_bin_header_layout():
    """The exact byte layout rust/src/util/io.rs expects."""
    arr = np.array([1.0], dtype=np.float32)
    p = "/tmp/rchg_test_hdr.bin"
    D.save_bin(p, arr)
    raw = open(p, "rb").read()
    assert raw[:4] == (0x52434847).to_bytes(4, "little")
    assert raw[4:8] == (0).to_bytes(4, "little")  # f32
    assert raw[8:12] == (1).to_bytes(4, "little")  # ndim
    assert raw[12:16] == (1).to_bytes(4, "little")  # dim0
    assert len(raw) == 20


def test_synth_cifar_deterministic_and_balanced():
    x1, y1 = D.synth_cifar(200, seed=42)
    x2, y2 = D.synth_cifar(200, seed=42)
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)
    counts = np.bincount(y1, minlength=10)
    assert (counts == 20).all()
    assert x1.min() >= 0.0 and x1.max() <= 1.0


def test_synth_cifar_classes_distinguishable():
    """A trivial nearest-class-mean classifier should beat chance by a lot —
    otherwise the accuracy experiments are meaningless."""
    x, y = D.synth_cifar(600, seed=1)
    xt, yt = D.synth_cifar(200, seed=2)
    means = np.stack([x[y == c].mean(axis=0).ravel() for c in range(10)])
    feats = xt.reshape(len(xt), -1)
    pred = np.argmin(
        ((feats[:, None, :] - means[None]) ** 2).sum(-1), axis=1
    )
    acc = (pred == yt).mean()
    assert acc > 0.5, f"nearest-mean acc {acc}"


def test_corpora_disjoint_and_deterministic():
    c1 = D.corpora(80_000)
    c2 = D.corpora(80_000)
    assert set(c1) == {"jaxsrc", "npsrc", "pysrc"}
    for k in c1:
        np.testing.assert_array_equal(c1[k], c2[k])
        assert len(c1[k]) == 80_000
        assert c1[k].min() >= 0 and c1[k].max() <= 255
    assert not np.array_equal(c1["jaxsrc"][:1000], c1["npsrc"][:1000])


def test_split_corpus_disjoint():
    toks = np.arange(1000, dtype=np.int32)
    tr, ev = D.split_corpus(toks)
    assert len(tr) + len(ev) == 1000
    assert tr[-1] < ev[0]


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
def test_manifest_consistency():
    with open(os.path.join(ART, "manifest.json")) as f:
        manifest = json.load(f)
    meta = manifest.pop("_meta")
    assert set(meta["group_configs"]) == {"r1c4", "r2c2", "r2c4"}
    for name, entry in manifest.items():
        path = os.path.join(ART, entry["path"])
        assert os.path.exists(path), f"{name} artifact missing"
        text = open(path).read()
        assert "HloModule" in text, f"{name} is not HLO text"
        assert len(entry["args"]) >= 4
        for arg in entry["args"]:
            assert arg["dtype"] in ("f32", "i32")
            assert all(d > 0 for d in arg["shape"])


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built",
)
def test_hlo_entry_parameter_counts():
    """HLO text parameter count matches the manifest arg list."""
    with open(os.path.join(ART, "manifest.json")) as f:
        manifest = json.load(f)
    manifest.pop("_meta")
    for name, entry in list(manifest.items())[:4]:
        text = open(os.path.join(ART, entry["path"])).read()
        entry_line = [
            l for l in text.splitlines() if l.startswith("ENTRY") or "ENTRY" in l
        ][0]
        n_params = entry_line.count("parameter") or entry_line.count("f32[") + entry_line.count("s32[")
        # Weak check: at least as many typed params as manifest args.
        assert len(entry["args"]) <= max(n_params, len(entry["args"]))
