"""L1 Pallas kernels (build-time only; lowered into the model HLO)."""

from .crossbar_mvm import fault_inject, imc_linear, imc_matmul  # noqa: F401
