"""L1 Pallas kernel: bit-sliced signed crossbar MVM (the IMC subarray of
Fig 2c).

The analog subarray computes, for each significance slice ``c`` and each
output column, a bit-line MAC of the input activations against the cell
conductances; the multiplexed ADC digitizes each bit-line, the
shift-and-add circuit scales slice ``c`` by its significance ``L^(cols-1-c)``
and the subtractor takes positive-array minus negative-array.

Layout contract with the rust coordinator (``rust/src/runtime``):

* ``x``            : ``[B, K]``  activations (logical input features)
* ``pos_planes``   : ``[C, K*r, N]`` positive-array cell values (0..L-1,
                     already fault-injected by the coordinator)
* ``neg_planes``   : ``[C, K*r, N]`` negative-array cell values
* ``sigs``         : ``[C]`` significance per slice, MSB first
* row grouping ``r``: physical row ``k*r + j`` belongs to logical input
                     ``k`` (rows of one group carry the same voltage) —
                     the wrapper repeats activations accordingly.

Hardware adaptation (paper targets a ReRAM macro, we target TPU-style
tiling): each grid step stages one ``[TB, Kr] × [Kr, TN]`` block pair in
VMEM and performs ``C`` MXU-shaped matmuls (slices are a static unroll,
C ≤ 4 for every paper config) followed by the shift-add reduction. The
BlockSpec index maps express the HBM→VMEM schedule the paper realizes
with its tile/PE hierarchy. ``interpret=True`` everywhere: the CPU PJRT
client cannot execute Mosaic custom-calls; numerics are identical.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _adc_quantize(bitline, adc_bits, max_code):
    """Model a saturating linear ADC on a bit-line partial sum.

    ``max_code`` is the full-scale input current (in weight-LSB units); the
    ADC maps [0, max_code] onto ``2**adc_bits`` codes. Ideal ADC when
    ``adc_bits`` is None.
    """
    if adc_bits is None:
        return bitline
    levels = float(2**adc_bits - 1)
    step = max_code / levels
    return jnp.clip(jnp.round(bitline / step), 0.0, levels) * step


def _make_kernel(n_slices, adc_bits, adc_max):
    def kernel(x_ref, pos_ref, neg_ref, sig_ref, o_ref):
        x = x_ref[...]
        acc = jnp.zeros(o_ref.shape, dtype=jnp.float32)
        # Static unroll over significance slices (C <= 4 in practice): each
        # iteration is one MXU matmul pair + shift-add.
        for c in range(n_slices):
            bl_pos = jnp.dot(x, pos_ref[c], preferred_element_type=jnp.float32)
            bl_neg = jnp.dot(x, neg_ref[c], preferred_element_type=jnp.float32)
            bl_pos = _adc_quantize(bl_pos, adc_bits, adc_max)
            bl_neg = _adc_quantize(bl_neg, adc_bits, adc_max)
            acc = acc + sig_ref[c] * (bl_pos - bl_neg)
        o_ref[...] = acc

    return kernel


def imc_matmul(
    x_phys,
    pos_planes,
    neg_planes,
    sigs,
    *,
    adc_bits=None,
    block_b=None,
    block_n=None,
    interpret=True,
):
    """Crossbar MVM over *physical* rows (activations already row-grouped).

    ``x_phys``: [B, Kr]; planes: [C, Kr, N]; returns [B, N] float32.
    """
    b, kr = x_phys.shape
    n_slices, kr2, n = pos_planes.shape
    assert kr == kr2, f"row mismatch {kr} vs {kr2}"
    assert neg_planes.shape == pos_planes.shape
    assert sigs.shape == (n_slices,)

    # Tile sizes: MXU-shaped (128) when the problem is big enough, otherwise
    # whole-dimension blocks. Interpret mode runs either way; the BlockSpec
    # is the VMEM schedule statement for a real TPU lowering.
    tb = block_b or min(b, 128)
    tn = block_n or min(n, 128)
    grid = (pl.cdiv(b, tb), pl.cdiv(n, tn))

    # Full-scale bit-line current: every cell at max conductance with every
    # input at full scale. Used only by the saturating-ADC model.
    adc_max = float(kr)

    kernel = _make_kernel(n_slices, adc_bits, adc_max)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, kr), lambda i, j: (i, 0)),
            pl.BlockSpec((n_slices, kr, tn), lambda i, j: (0, 0, j)),
            pl.BlockSpec((n_slices, kr, tn), lambda i, j: (0, 0, j)),
            pl.BlockSpec((n_slices,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((tb, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        interpret=interpret,
    )(x_phys, pos_planes, neg_planes, sigs)


def imc_linear(
    x,
    pos_planes,
    neg_planes,
    sigs,
    *,
    rows_per_weight=1,
    adc_bits=None,
    interpret=True,
):
    """Logical IMC linear layer: handles the row-grouping input fan-out.

    ``x``: [B, K]; planes: [C, K*rows_per_weight, N]. Rows of one weight
    group share the input voltage, so activations are repeated
    ``rows_per_weight`` times along the feature axis (interleaved, matching
    physical row ``k*r + j``).
    """
    if rows_per_weight > 1:
        x = jnp.repeat(x, rows_per_weight, axis=1)
    return imc_matmul(
        x, pos_planes, neg_planes, sigs, adc_bits=adc_bits, interpret=interpret
    )


def fault_inject(x, f0, f1, levels):
    """L1 elementwise fault application, Eq. (1):
    ``(1 - F0 - F1) ⊙ X + (L-1) · F0`` as a Pallas kernel."""

    def kernel(x_ref, f0_ref, f1_ref, o_ref):
        free = 1.0 - f0_ref[...] - f1_ref[...]
        o_ref[...] = free * x_ref[...] + (levels - 1.0) * f0_ref[...]

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), f0.astype(jnp.float32), f1.astype(jnp.float32))


# Convenience: jitted reference-precision entry point used by model.py.
imc_linear_f32 = partial(imc_linear, adc_bits=None, interpret=True)
