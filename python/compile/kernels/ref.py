"""Pure-jnp correctness oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here; pytest
(``python/tests``) sweeps shapes/configs with hypothesis and asserts
allclose between kernel and oracle. ``imc_matmul_ref`` is literally
``x @ dequant(d(X̃⁺) − d(X̃⁻))`` from the paper's Eq. (2).
"""

import jax.numpy as jnp


def adc_quantize_ref(bitline, adc_bits, max_code):
    if adc_bits is None:
        return bitline
    levels = float(2**adc_bits - 1)
    step = max_code / levels
    return jnp.clip(jnp.round(bitline / step), 0.0, levels) * step


def imc_matmul_ref(x_phys, pos_planes, neg_planes, sigs, *, adc_bits=None):
    """Reference bit-sliced crossbar MVM: shift-add of per-slice matmuls."""
    b, kr = x_phys.shape
    n_slices = pos_planes.shape[0]
    adc_max = float(kr)
    out = jnp.zeros((b, pos_planes.shape[2]), dtype=jnp.float32)
    for c in range(n_slices):
        bl_pos = adc_quantize_ref(x_phys @ pos_planes[c], adc_bits, adc_max)
        bl_neg = adc_quantize_ref(x_phys @ neg_planes[c], adc_bits, adc_max)
        out = out + sigs[c] * (bl_pos - bl_neg)
    return out


def imc_linear_ref(x, pos_planes, neg_planes, sigs, *, rows_per_weight=1, adc_bits=None):
    if rows_per_weight > 1:
        x = jnp.repeat(x, rows_per_weight, axis=1)
    return imc_matmul_ref(x, pos_planes, neg_planes, sigs, adc_bits=adc_bits)


def reconstructed_weight_ref(pos_planes, neg_planes, sigs, rows_per_weight=1):
    """Collapse bit-planes into the effective logical weight matrix
    ``W̃[k, n] = Σ_c sig_c Σ_j (pos[c, k*r+j, n] − neg[c, k*r+j, n])`` —
    the faulty weight of Eq. (2) for every (input, output) pair."""
    c, kr, n = pos_planes.shape
    k = kr // rows_per_weight
    diff = (pos_planes - neg_planes).reshape(c, k, rows_per_weight, n).sum(axis=2)
    return jnp.einsum("c,ckn->kn", sigs, diff)


def fault_inject_ref(x, f0, f1, levels):
    """Eq. (1) reference."""
    return (1.0 - f0 - f1) * x + (levels - 1.0) * f0
