"""AOT export: lower the L2 deploy graphs (with the L1 Pallas kernel
inside) to HLO **text** artifacts the rust runtime loads via the `xla`
crate.

HLO text — not serialized HloModuleProto — is the interchange format:
jax ≥ 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 rejects; the text parser reassigns ids (see /opt/xla-example).

Artifacts written to ``artifacts/``:
  imc_linear_<cfg>.hlo.txt       standalone crossbar-MVM executable
  cnn_<arch>_<cfg>.hlo.txt       CNN deploy forward (batch 100)
  lm_<cfg>.hlo.txt               LM deploy forward  (batch 2 × ctx)
  manifest.json                  name → {path, args:[{name,shape,dtype}]}

Run AFTER train.py (reads nothing from it, but `make artifacts` orders
them; shapes depend only on the architecture tables).
"""

import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels.crossbar_mvm import imc_linear

ART = os.environ.get(
    "RCHG_ARTIFACTS",
    os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"),
)

# Grouping configurations exported for the rust side: name -> (rows, cols, L).
GROUP_CONFIGS = {
    "r1c4": (1, 4, 4),
    "r2c2": (2, 2, 4),
    "r2c4": (2, 4, 4),
}

CNN_EVAL_BATCH = 100
LM_EVAL_BATCH = 2


def to_hlo_text(lowered):
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _sigs(cols, levels):
    return [float(levels ** (cols - 1 - j)) for j in range(cols)]


def export(name, fn, arg_specs, manifest):
    """Lower `fn` at `arg_specs` and write `<name>.hlo.txt`."""
    lowered = jax.jit(fn).lower(*[_spec(s, d) for _, s, d in arg_specs])
    text = to_hlo_text(lowered)
    path = os.path.join(ART, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    manifest[name] = {
        "path": f"{name}.hlo.txt",
        "args": [
            {"name": n, "shape": list(s), "dtype": "i32" if d == jnp.int32 else "f32"}
            for n, s, d in arg_specs
        ],
    }
    print(f"  wrote {name}.hlo.txt ({len(text)/1024:.0f} KiB, {len(arg_specs)} args)")


def cnn_deploy_fn(arch, rows, n_slices):
    conv_names = [n for n, _ in M.cnn_param_shapes(arch) if n.startswith("conv")]

    def fn(x, *rest):
        conv = dict(zip(conv_names, rest[: len(conv_names)]))
        fc_pos, fc_neg, fc_sigs, fc_scale, fc_b = rest[len(conv_names) :]
        return (
            M.cnn_forward_deploy(
                conv, x, fc_pos, fc_neg, fc_sigs, fc_scale, fc_b, arch=arch, rows=rows
            ),
        )

    return fn, conv_names


def lm_deploy_fn(rows):
    names = [n for n, _ in M.lm_param_shapes()]

    def fn(tokens, *rest):
        trunk = dict(zip(names, rest[: len(names)]))
        head_pos, head_neg, head_sigs, head_scale = rest[len(names) :]
        return (
            M.lm_forward_deploy(
                trunk, tokens, head_pos, head_neg, head_sigs, head_scale, rows=rows
            ),
        )

    return fn, names


def main():
    os.makedirs(ART, exist_ok=True)
    manifest = {}

    for cfg_name, (rows, cols, levels) in GROUP_CONFIGS.items():
        n_slices = cols

        # ---- standalone crossbar-MVM microbench artifact ----------------
        k, n, b = 64, 10, 8
        export(
            f"imc_linear_{cfg_name}",
            lambda x, p, q, s: (imc_linear(x, p, q, s, rows_per_weight=rows),),
            [
                ("x", (b, k), jnp.float32),
                ("pos_planes", (n_slices, k * rows, n), jnp.float32),
                ("neg_planes", (n_slices, k * rows, n), jnp.float32),
                ("sigs", (n_slices,), jnp.float32),
            ],
            manifest,
        )

        # ---- CNN deploy graphs -------------------------------------------
        for arch in M.CNN_ARCHS:
            fn, conv_names = cnn_deploy_fn(arch, rows, n_slices)
            shapes = dict(M.cnn_param_shapes(arch))
            feat = shapes["fc_w"][0]
            args = [("x", (CNN_EVAL_BATCH, 32, 32, 3), jnp.float32)]
            args += [(cn, shapes[cn], jnp.float32) for cn in conv_names]
            args += [
                ("fc_pos", (n_slices, feat * rows, M.NUM_CLASSES), jnp.float32),
                ("fc_neg", (n_slices, feat * rows, M.NUM_CLASSES), jnp.float32),
                ("fc_sigs", (n_slices,), jnp.float32),
                ("fc_scale", (M.NUM_CLASSES,), jnp.float32),
                ("fc_b", (M.NUM_CLASSES,), jnp.float32),
            ]
            export(f"cnn_{arch}_{cfg_name}", fn, args, manifest)

        # ---- LM deploy graph ---------------------------------------------
        cfg = M.LM_CONFIG
        fn, names = lm_deploy_fn(rows)
        shapes = dict(M.lm_param_shapes())
        args = [("tokens", (LM_EVAL_BATCH, cfg["ctx"]), jnp.int32)]
        args += [(n_, shapes[n_], jnp.float32) for n_ in names]
        args += [
            ("head_pos", (n_slices, cfg["d_model"] * rows, cfg["vocab"]), jnp.float32),
            ("head_neg", (n_slices, cfg["d_model"] * rows, cfg["vocab"]), jnp.float32),
            ("head_sigs", (n_slices,), jnp.float32),
            ("head_scale", (cfg["vocab"],), jnp.float32),
        ]
        export(f"lm_{cfg_name}", fn, args, manifest)

    manifest["_meta"] = {
        "group_configs": {k: list(v) for k, v in GROUP_CONFIGS.items()},
        "cnn_archs": {k: v for k, v in M.CNN_ARCHS.items()},
        "cnn_eval_batch": CNN_EVAL_BATCH,
        "lm_eval_batch": LM_EVAL_BATCH,
        "lm_config": M.LM_CONFIG,
        "num_classes": M.NUM_CLASSES,
    }
    with open(os.path.join(ART, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"  wrote manifest.json ({len(manifest)-1} executables)")


if __name__ == "__main__":
    main()
