"""Bit-plane packing reference (mirrors rust/src/grouping/bitmap.rs).

The rust coordinator packs each weight's decomposition into plane tensors
``[C, K*r, N]`` consumed by the L1 kernel. This module is the python-side
reference for that layout, used by the pytest suite to validate the
deploy graphs end-to-end and by quickstart demos. Cell layout:
``cells[col*rows + row]`` (column 0 = MSB), physical row ``k*r + row``.
"""

import numpy as np


def encode_ideal(w, rows, cols, levels):
    """Ideal sign decomposition + generalized base-L digits, identical to
    ``Decomposition::encode_ideal``. Returns (pos_cells, neg_cells), each
    length rows*cols."""
    max_per_array = rows * (levels**cols - 1)
    assert abs(w) <= max_per_array, f"weight {w} out of range"
    mag = abs(int(w))
    cells = np.zeros(rows * cols, np.int64)
    cap_per_col = (levels - 1) * rows
    for col in range(cols):
        sig = levels ** (cols - 1 - col)
        lower_max = rows * (sig - 1)
        take = min(mag // sig, cap_per_col)
        while mag - take * sig > lower_max:
            take += 1
        mag -= take * sig
        for row in range(rows):
            v = min(take, levels - 1)
            cells[col * rows + row] = v
            take -= v
        assert take == 0
    assert mag == 0
    zeros = np.zeros_like(cells)
    return (cells, zeros) if w >= 0 else (zeros, cells)


def pack_planes(w_int, rows, cols, levels):
    """Pack an integer weight matrix [K, N] into (pos, neg) plane tensors
    [C, K*rows, N] (float32)."""
    k, n = w_int.shape
    pos = np.zeros((cols, k * rows, n), np.float32)
    neg = np.zeros((cols, k * rows, n), np.float32)
    for ki in range(k):
        for ni in range(n):
            p, q = encode_ideal(int(w_int[ki, ni]), rows, cols, levels)
            for col in range(cols):
                for row in range(rows):
                    pos[col, ki * rows + row, ni] = p[col * rows + row]
                    neg[col, ki * rows + row, ni] = q[col * rows + row]
    return pos, neg


def sigs(cols, levels):
    return np.array([levels ** (cols - 1 - j) for j in range(cols)], np.float32)


def quantize_sym(w, max_int):
    """Per-column symmetric quantization of [K, N] float weights: returns
    (w_int [K,N], scale [N])."""
    absmax = np.abs(w).max(axis=0)
    scale = np.where(absmax > 0, absmax / max_int, 1.0).astype(np.float32)
    w_int = np.clip(np.round(w / scale), -max_int, max_int).astype(np.int64)
    return w_int, scale
