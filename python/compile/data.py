"""Build-time datasets.

* **Synthetic CIFAR** — the paper evaluates on CIFAR-10/ImageNet, which are
  not available in this offline environment. We substitute a deterministic
  procedurally-generated 10-class 32×32×3 set (stripes / checkers / disks /
  crosses / gradients × two palettes, with random phase, jitter and noise).
  What matters for the reproduction is the *relative* accuracy of grouping
  configurations under SAFs, not ImageNet absolute accuracy (DESIGN.md §3).

* **Byte corpora** — stand-ins for WikiText-2 / PTB / C4: three disjoint
  real text corpora assembled from source trees shipped in the image
  (jax, numpy, python stdlib). Byte-level tokenization, 256-way vocab.
"""

import os
import sys

import numpy as np


# --------------------------------------------------------------------------
# Synthetic CIFAR
# --------------------------------------------------------------------------

_PALETTES = [
    ((0.9, 0.2, 0.1), (0.1, 0.3, 0.9)),
    ((0.2, 0.8, 0.3), (0.8, 0.7, 0.1)),
]


def _pattern(cls, rng):
    """One 32×32×3 image for class `cls` (0..9)."""
    kind = cls % 5
    fg, bg = _PALETTES[cls // 5]
    yy, xx = np.mgrid[0:32, 0:32].astype(np.float32)
    phase = rng.uniform(0, 8)
    freq = rng.uniform(0.55, 0.8)
    if kind == 0:  # horizontal stripes
        m = ((yy * freq + phase) % 4 < 2).astype(np.float32)
    elif kind == 1:  # vertical stripes
        m = ((xx * freq + phase) % 4 < 2).astype(np.float32)
    elif kind == 2:  # checkerboard
        m = ((((xx + phase) // 4) + ((yy + phase) // 4)) % 2).astype(np.float32)
    elif kind == 3:  # disk
        cx, cy = rng.uniform(10, 22, size=2)
        r = rng.uniform(6, 10)
        m = (((xx - cx) ** 2 + (yy - cy) ** 2) < r * r).astype(np.float32)
    else:  # diagonal gradient + cross
        m = (((xx + yy) * 0.5 * freq + phase) % 6 < 3).astype(np.float32)
    img = np.empty((32, 32, 3), np.float32)
    for ch in range(3):
        img[..., ch] = m * fg[ch] + (1 - m) * bg[ch]
    img += rng.normal(0, 0.15, img.shape).astype(np.float32)
    return np.clip(img, 0.0, 1.0)


def synth_cifar(n, seed):
    """Return (x [n,32,32,3] f32, y [n] i32), class-balanced, deterministic."""
    rng = np.random.default_rng(seed)
    x = np.empty((n, 32, 32, 3), np.float32)
    y = np.empty((n,), np.int32)
    for i in range(n):
        cls = i % 10
        x[i] = _pattern(cls, rng)
        y[i] = cls
    perm = rng.permutation(n)
    return x[perm], y[perm]


# --------------------------------------------------------------------------
# Byte corpora
# --------------------------------------------------------------------------


def _collect_py_bytes(root, limit_bytes):
    """Concatenate .py sources under `root` (sorted walk → deterministic)."""
    chunks = []
    total = 0
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames.sort()
        for fn in sorted(filenames):
            if not fn.endswith(".py"):
                continue
            try:
                with open(os.path.join(dirpath, fn), "rb") as f:
                    data = f.read()
            except OSError:
                continue
            chunks.append(data)
            total += len(data)
            if total >= limit_bytes:
                return b"\n".join(chunks)[:limit_bytes]
    return b"\n".join(chunks)[:limit_bytes]


def corpora(limit_bytes=400_000):
    """Three disjoint byte corpora: {'jaxsrc', 'npsrc', 'pysrc'}."""
    import jax as _jax
    import numpy as _np

    roots = {
        "jaxsrc": os.path.dirname(_jax.__file__),
        "npsrc": os.path.dirname(_np.__file__),
        "pysrc": os.path.dirname(os.__file__),  # python stdlib
    }
    out = {}
    for name, root in roots.items():
        data = _collect_py_bytes(root, limit_bytes)
        assert len(data) > 50_000, f"corpus {name} too small ({len(data)}B at {root})"
        out[name] = np.frombuffer(data, dtype=np.uint8).astype(np.int32)
    return out


def split_corpus(tokens, train_frac=0.85):
    cut = int(len(tokens) * train_frac)
    return tokens[:cut], tokens[cut:]


def batch_tokens(tokens, batch, ctx, rng):
    """Sample a [batch, ctx+1] matrix of token windows."""
    starts = rng.integers(0, len(tokens) - ctx - 1, size=batch)
    return np.stack([tokens[s : s + ctx + 1] for s in starts])


# --------------------------------------------------------------------------
# RCHG .bin export (mirrors rust/src/util/io.rs)
# --------------------------------------------------------------------------

MAGIC = 0x52434847
_DTYPES = {np.float32: 0, np.int32: 1, np.uint8: 2}


def save_bin(path, arr):
    arr = np.ascontiguousarray(arr)
    code = {np.dtype(np.float32): 0, np.dtype(np.int32): 1, np.dtype(np.uint8): 2}[
        arr.dtype
    ]
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "wb") as f:
        header = np.array(
            [MAGIC, code, arr.ndim] + list(arr.shape), dtype="<u4"
        ).tobytes()
        f.write(header)
        f.write(arr.astype(arr.dtype.newbyteorder("<")).tobytes())


def load_bin(path):
    with open(path, "rb") as f:
        raw = f.read()
    head = np.frombuffer(raw[:12], dtype="<u4")
    assert head[0] == MAGIC, f"bad magic in {path}"
    code, ndim = int(head[1]), int(head[2])
    dims = np.frombuffer(raw[12 : 12 + 4 * ndim], dtype="<u4").astype(int)
    dtype = {0: np.float32, 1: np.int32, 2: np.uint8}[code]
    payload = np.frombuffer(raw[12 + 4 * ndim :], dtype=np.dtype(dtype).newbyteorder("<"))
    return payload.reshape(dims).astype(dtype)


if __name__ == "__main__":
    # Smoke: generate a tiny set and print stats.
    x, y = synth_cifar(100, 0)
    print("cifar", x.shape, x.mean(), np.bincount(y))
    cs = corpora(100_000)
    for k, v in cs.items():
        print(k, v.shape, v[:16])
    sys.exit(0)
