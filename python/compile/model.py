"""L2: JAX model definitions (forward graphs) for the evaluation models.

Two families, matching the paper's evaluation:

* **CNN** (§VII Table I / Fig 8 / Fig 9 substitutes): small convnets for
  32×32×3 classification. Conv weights enter the deployed graph as
  *faulty dequantized floats* (the rust coordinator reconstructs
  ``w̃ = scale · (d(X̃⁺) − d(X̃⁻))`` — with an ideal ADC this is
  numerically identical to running every MAC through the crossbar);
  the FC classifier head runs through the L1 Pallas crossbar kernel with
  raw bit-planes, so the AOT artifact exercises the full subarray
  dataflow end-to-end.

* **LM** (Table III substitute): an OPT-architecture decoder-only
  transformer (pre-LN, learned positions, tied embeddings), byte-level
  vocabulary. The tied LM head runs through the Pallas crossbar kernel.

The float (training) forwards share all shape logic with the deployed
forwards, so the trained parameters drop straight into the deploy path.
"""

from functools import partial

import jax
import jax.numpy as jnp

from .kernels.crossbar_mvm import imc_linear

# --------------------------------------------------------------------------
# CNN family
# --------------------------------------------------------------------------

# name -> (conv channel plan [(out_ch, stride), ...], fc width implied by
# last conv). Input is NHWC 32x32x3; GAP before the FC head.
CNN_ARCHS = {
    # Stand-in for ResNet-20 (CIFAR-scale baseline in the paper).
    "cnn_s": [(16, 1), (32, 2), (32, 1), (64, 2), (64, 1)],
    # Stand-in for ResNet-18.
    "cnn_m": [(24, 1), (48, 2), (48, 1), (96, 2), (96, 1)],
    # Stand-in for ResNet-50 (deeper).
    "cnn_d": [(32, 1), (32, 1), (64, 2), (64, 1), (96, 2), (96, 1)],
    # Stand-in for VGG-16 (wider, VGG-style plain stacking).
    "vgg_n": [(32, 1), (32, 1), (64, 2), (64, 1), (128, 2), (128, 1)],
}

NUM_CLASSES = 10


def cnn_param_shapes(arch):
    """Ordered (name, shape) list for one CNN architecture."""
    plan = CNN_ARCHS[arch]
    shapes = []
    cin = 3
    for i, (cout, _stride) in enumerate(plan):
        shapes.append((f"conv{i}_w", (3, 3, cin, cout)))
        shapes.append((f"conv{i}_b", (cout,)))
        cin = cout
    shapes.append(("fc_w", (cin, NUM_CLASSES)))
    shapes.append(("fc_b", (NUM_CLASSES,)))
    return shapes


def cnn_init(arch, key):
    params = {}
    for name, shape in cnn_param_shapes(arch):
        key, sub = jax.random.split(key)
        if name.endswith("_b"):
            params[name] = jnp.zeros(shape, jnp.float32)
        else:
            fan_in = 1
            for d in shape[:-1]:
                fan_in *= d
            params[name] = jax.random.normal(sub, shape, jnp.float32) * jnp.sqrt(
                2.0 / fan_in
            )
    return params


def _conv(x, w, stride):
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def cnn_features(params, x, arch):
    """Shared conv trunk: NHWC image -> [B, C_last] pooled features."""
    h = x
    for i, (_cout, stride) in enumerate(CNN_ARCHS[arch]):
        h = _conv(h, params[f"conv{i}_w"], stride) + params[f"conv{i}_b"]
        h = jax.nn.relu(h)
    return h.mean(axis=(1, 2))  # global average pool


def cnn_forward_float(params, x, arch):
    """Float forward (training / ideal-accuracy reference)."""
    feats = cnn_features(params, x, arch)
    return feats @ params["fc_w"] + params["fc_b"]


def cnn_forward_deploy(
    conv_params, x, fc_pos, fc_neg, fc_sigs, fc_scale, fc_b, *, arch, rows
):
    """Deployed forward: conv weights are (faulty) floats, the FC head runs
    on the Pallas crossbar kernel from raw bit-planes.

    ``fc_scale``: per-output-column dequantization scale (quantizer's).
    """
    feats = cnn_features(conv_params, x, arch)
    logits_int = imc_linear(feats, fc_pos, fc_neg, fc_sigs, rows_per_weight=rows)
    return logits_int * fc_scale + fc_b


# --------------------------------------------------------------------------
# OPT-like language model
# --------------------------------------------------------------------------

LM_CONFIG = {
    "vocab": 256,  # byte-level
    "d_model": 96,
    "n_heads": 4,
    "n_layers": 3,
    "d_ff": 384,
    "ctx": 96,
}


def lm_param_shapes(cfg=LM_CONFIG):
    d, f, v, t = cfg["d_model"], cfg["d_ff"], cfg["vocab"], cfg["ctx"]
    shapes = [("embed", (v, d)), ("pos", (t, d))]
    for i in range(cfg["n_layers"]):
        p = f"l{i}_"
        shapes += [
            (p + "ln1_g", (d,)),
            (p + "ln1_b", (d,)),
            (p + "qkv_w", (d, 3 * d)),
            (p + "qkv_b", (3 * d,)),
            (p + "o_w", (d, d)),
            (p + "o_b", (d,)),
            (p + "ln2_g", (d,)),
            (p + "ln2_b", (d,)),
            (p + "fc1_w", (d, f)),
            (p + "fc1_b", (f,)),
            (p + "fc2_w", (f, d)),
            (p + "fc2_b", (d,)),
        ]
    shapes += [("lnf_g", (d,)), ("lnf_b", (d,))]
    return shapes


def lm_init(key, cfg=LM_CONFIG):
    params = {}
    for name, shape in lm_param_shapes(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(("_b", "ln1_g", "ln2_g", "lnf_g")) or name.endswith("_g"):
            params[name] = (
                jnp.ones(shape, jnp.float32)
                if name.endswith("_g")
                else jnp.zeros(shape, jnp.float32)
            )
        elif name == "pos":
            params[name] = jax.random.normal(sub, shape, jnp.float32) * 0.01
        else:
            params[name] = jax.random.normal(sub, shape, jnp.float32) * (
                0.02 if name == "embed" else 1.0 / jnp.sqrt(shape[0])
            )
    return params


def _ln(x, g, b):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + 1e-5) * g + b


def _attn(x, qkv_w, qkv_b, o_w, o_b, n_heads):
    b, t, d = x.shape
    hd = d // n_heads
    qkv = x @ qkv_w + qkv_b
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def heads(z):
        return z.reshape(b, t, n_heads, hd).transpose(0, 2, 1, 3)

    q, k, v = heads(q), heads(k), heads(v)
    att = (q @ k.transpose(0, 1, 3, 2)) / jnp.sqrt(float(hd))
    mask = jnp.tril(jnp.ones((t, t), bool))
    att = jnp.where(mask, att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    out = (att @ v).transpose(0, 2, 1, 3).reshape(b, t, d)
    return out @ o_w + o_b


def lm_trunk(params, tokens, cfg=LM_CONFIG):
    """Embedding + transformer stack + final LN: tokens -> [B, T, d]."""
    b, t = tokens.shape
    h = params["embed"][tokens] + params["pos"][:t]
    for i in range(cfg["n_layers"]):
        p = f"l{i}_"
        a = _attn(
            _ln(h, params[p + "ln1_g"], params[p + "ln1_b"]),
            params[p + "qkv_w"],
            params[p + "qkv_b"],
            params[p + "o_w"],
            params[p + "o_b"],
            cfg["n_heads"],
        )
        h = h + a
        m = _ln(h, params[p + "ln2_g"], params[p + "ln2_b"])
        m = jax.nn.gelu(m @ params[p + "fc1_w"] + params[p + "fc1_b"])
        h = h + (m @ params[p + "fc2_w"] + params[p + "fc2_b"])
    return _ln(h, params["lnf_g"], params["lnf_b"])


def lm_forward_float(params, tokens, cfg=LM_CONFIG):
    """Training forward: logits via the tied embedding matrix."""
    h = lm_trunk(params, tokens, cfg)
    return h @ params["embed"].T


def lm_forward_deploy(
    trunk_params, tokens, head_pos, head_neg, head_sigs, head_scale, *, rows, cfg=LM_CONFIG
):
    """Deployed forward: trunk weights are (faulty) floats; the tied LM head
    (embedding transpose) runs on the Pallas crossbar kernel.

    ``head_scale``: per-vocab-column dequant scale, shape [vocab].
    """
    h = lm_trunk(trunk_params, tokens, cfg)
    b, t, d = h.shape
    flat = h.reshape(b * t, d)
    logits = imc_linear(flat, head_pos, head_neg, head_sigs, rows_per_weight=rows)
    return (logits * head_scale).reshape(b, t, cfg["vocab"])


def lm_loss(params, tokens, cfg=LM_CONFIG):
    """Next-token cross-entropy (mean over positions)."""
    logits = lm_forward_float(params, tokens[:, :-1], cfg)
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return nll.mean()


def cnn_loss(params, x, y, arch):
    logits = cnn_forward_float(params, x, arch)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, y[:, None], axis=-1).mean()


# jitted train-step factories -------------------------------------------------


def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": 0}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mhat_scale = 1.0 / (1 - b1**t)
    vhat_scale = 1.0 / (1 - b2**t)
    new_params = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + eps),
        params,
        m,
        v,
    )
    return new_params, {"m": m, "v": v, "t": t}


def make_cnn_train_step(arch, lr=1e-3):
    @jax.jit
    def step(params, opt, x, y):
        loss, grads = jax.value_and_grad(partial(cnn_loss, arch=arch))(params, x, y)
        params, opt = adam_update(params, grads, opt, lr)
        return params, opt, loss

    return step


def make_lm_train_step(lr=3e-4, cfg=LM_CONFIG):
    @jax.jit
    def step(params, opt, tokens):
        loss, grads = jax.value_and_grad(partial(lm_loss, cfg=cfg))(params, tokens)
        params, opt = adam_update(params, grads, opt, lr)
        return params, opt, loss

    return step
