"""Build-time compile path (L1 Pallas kernels + L2 JAX models + AOT export).

Nothing in this package is imported at runtime; the rust coordinator only
consumes the HLO-text artifacts and weight banks it emits.
"""
