"""Build-time training: produce the evaluation models' float weights.

Runs once as part of ``make artifacts`` (never at runtime). Trains

* four small CNNs (``cnn_s``, ``cnn_m``, ``cnn_d``, ``vgg_n`` — the
  ResNet-20/18/50 / VGG-16 stand-ins) on the synthetic CIFAR set, and
* the OPT-like byte-level LM on the combined source-code corpus,

then exports weights, test data and eval token streams to ``artifacts/``
in the RCHG .bin format shared with the rust side.

Environment knobs:
  RCHG_FAST=1        tiny step counts (CI smoke)
  RCHG_STEPS=<n>     override CNN train steps
  RCHG_LM_STEPS=<n>  override LM train steps
"""

import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data as D
from . import model as M

ART = os.environ.get("RCHG_ARTIFACTS", os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))

FAST = os.environ.get("RCHG_FAST") == "1"
CNN_STEPS = int(os.environ.get("RCHG_STEPS", "60" if FAST else "900"))
LM_STEPS = int(os.environ.get("RCHG_LM_STEPS", "30" if FAST else "700"))
TRAIN_N = 1000 if FAST else 6000
TEST_N = 200 if FAST else 1000
BATCH = 64
LM_BATCH = 8


def train_cnn(arch, x_train, y_train, x_test, y_test, seed=0):
    key = jax.random.PRNGKey(seed)
    params = M.cnn_init(arch, key)
    opt = M.adam_init(params)
    step = M.make_cnn_train_step(arch)
    rng = np.random.default_rng(seed + 1)
    n = len(x_train)
    t0 = time.time()
    loss = float("nan")
    for it in range(CNN_STEPS):
        idx = rng.integers(0, n, size=BATCH)
        params, opt, loss = step(params, opt, x_train[idx], y_train[idx])
        if it % 100 == 0:
            print(f"  [{arch}] step {it:4d} loss {float(loss):.4f}", flush=True)
    # Test accuracy in batches.
    preds = []
    for i in range(0, len(x_test), 200):
        logits = M.cnn_forward_float(params, x_test[i : i + 200], arch)
        preds.append(np.argmax(np.asarray(logits), axis=-1))
    acc = float((np.concatenate(preds) == y_test).mean())
    print(
        f"  [{arch}] done in {time.time()-t0:.1f}s, final loss {float(loss):.4f}, "
        f"float test acc {acc*100:.2f}%",
        flush=True,
    )
    return params, acc


def train_lm(train_tokens, eval_streams, seed=0):
    cfg = M.LM_CONFIG
    key = jax.random.PRNGKey(100 + seed)
    params = M.lm_init(key)
    opt = M.adam_init(params)
    step = M.make_lm_train_step()
    rng = np.random.default_rng(seed + 7)
    t0 = time.time()
    loss = float("nan")
    for it in range(LM_STEPS):
        batch = D.batch_tokens(train_tokens, LM_BATCH, cfg["ctx"], rng)
        params, opt, loss = step(params, opt, jnp.asarray(batch))
        if it % 50 == 0:
            print(f"  [lm] step {it:4d} loss {float(loss):.4f}", flush=True)
    # Float perplexity on each eval stream.
    ppls = {}
    for name, stream in eval_streams.items():
        ppls[name] = float(eval_ppl(params, stream))
    print(
        f"  [lm] done in {time.time()-t0:.1f}s, float ppl: "
        + ", ".join(f"{k}={v:.2f}" for k, v in ppls.items()),
        flush=True,
    )
    return params, ppls


def eval_ppl(params, stream, max_windows=120):
    """Float perplexity over non-overlapping ctx windows of a token stream."""
    cfg = M.LM_CONFIG
    ctx = cfg["ctx"]
    n_win = min((len(stream) - 1) // ctx, max_windows)
    total_nll, total_tok = 0.0, 0
    fwd = jax.jit(lambda p, t: M.lm_forward_float(p, t))
    for i in range(0, n_win, LM_BATCH):
        rows = []
        for j in range(i, min(i + LM_BATCH, n_win)):
            rows.append(stream[j * ctx : j * ctx + ctx + 1])
        batch = jnp.asarray(np.stack(rows))
        logits = fwd(params, batch[:, :-1])
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, batch[:, 1:, None], axis=-1)[..., 0]
        total_nll += float(nll.sum())
        total_tok += int(nll.size)
    return np.exp(total_nll / max(total_tok, 1))


def save_params(params, shapes, outdir, meta_extra=None):
    os.makedirs(outdir, exist_ok=True)
    order = []
    for name, shape in shapes:
        arr = np.asarray(params[name], dtype=np.float32)
        assert arr.shape == tuple(shape), f"{name}: {arr.shape} vs {shape}"
        D.save_bin(os.path.join(outdir, f"{name}.bin"), arr)
        order.append({"name": name, "shape": list(shape)})
    meta = {"params": order}
    if meta_extra:
        meta.update(meta_extra)
    with open(os.path.join(outdir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=2, sort_keys=True)


def main():
    os.makedirs(ART, exist_ok=True)
    print(f"== build-time training (fast={FAST}, cnn_steps={CNN_STEPS}, lm_steps={LM_STEPS})")

    # ---------------- CNNs on synthetic CIFAR ----------------------------
    print("== dataset: synthetic CIFAR")
    x_train, y_train = D.synth_cifar(TRAIN_N, seed=1234)
    x_test, y_test = D.synth_cifar(TEST_N, seed=9999)
    D.save_bin(os.path.join(ART, "data", "cifar_test_x.bin"), x_test)
    D.save_bin(os.path.join(ART, "data", "cifar_test_y.bin"), y_test)

    cnn_results = {}
    for arch in M.CNN_ARCHS:
        print(f"== training {arch}")
        params, acc = train_cnn(arch, x_train, jnp.asarray(y_train), x_test, y_test)
        save_params(
            params,
            M.cnn_param_shapes(arch),
            os.path.join(ART, "weights", arch),
            {"arch": arch, "plan": M.CNN_ARCHS[arch], "float_acc": acc},
        )
        cnn_results[arch] = acc

    # ---------------- LM on byte corpora ---------------------------------
    print("== corpora")
    corps = D.corpora()
    train_parts, eval_streams = [], {}
    for name, toks in corps.items():
        tr, ev = D.split_corpus(toks)
        train_parts.append(tr)
        eval_streams[name] = ev
        D.save_bin(os.path.join(ART, "data", f"lm_eval_{name}.bin"), ev.astype(np.int32))
    train_tokens = np.concatenate(train_parts)
    print(f"   train tokens: {len(train_tokens)}, eval streams: "
          + ", ".join(f"{k}:{len(v)}" for k, v in eval_streams.items()))

    print("== training lm")
    lm_params, ppls = train_lm(train_tokens, eval_streams)
    save_params(
        lm_params,
        M.lm_param_shapes(),
        os.path.join(ART, "weights", "lm"),
        {"config": M.LM_CONFIG, "float_ppl": ppls},
    )

    with open(os.path.join(ART, "training_summary.json"), "w") as f:
        json.dump({"cnn_float_acc": cnn_results, "lm_float_ppl": ppls}, f, indent=2)
    print("== training complete")


if __name__ == "__main__":
    main()
